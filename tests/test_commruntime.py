"""CommRuntime spec/op API: byte accounting per link class, fabric-priced
costs shared with netsim (same CommSpec -> same bytes-on-link), the
reconfiguration hook, and single-device degradation of every lowering."""

import dataclasses

import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.paper_models import MIXTRAL_8X7B
from repro.core import commruntime as cr
from repro.core.fabric import FabricConfig, make_fabric
from repro.core.netsim import GateTraceGenerator


# ---------------------------------------------------------------------------
# CommSpec
# ---------------------------------------------------------------------------


def test_spec_validation_and_factorization():
    s = cr.CommSpec(axis="model", axis_size=8, group_size=4)
    assert s.hierarchical and s.num_groups == 2
    # degenerate group sizes run the flat lowering
    assert not cr.CommSpec(axis="model", axis_size=8, group_size=1).hierarchical
    assert not cr.CommSpec(axis="model", axis_size=8, group_size=8).hierarchical
    with pytest.raises(ValueError):
        cr.CommSpec(axis="model", axis_size=8, group_size=3)
    with pytest.raises(ValueError):
        cr.CommSpec(axis="model", axis_size=4, dest_perm=(0, 0, 1, 2))


def test_spec_from_plan_degenerate_group():
    from repro.parallel.sharding import ShardingPlan

    plan = ShardingPlan(("data",), "model", 2, None, 4)
    # group spanning the whole axis -> flat lowering (a one-group hierarchy)
    s = cr.CommSpec.from_plan(plan, group_size=4)
    assert s.axis == "model" and s.axis_size == 2 and s.group_size == 1
    s1 = cr.CommSpec.from_plan(ShardingPlan((), None, 1, None, 1))
    assert s1.axis is None and s1.axis_size == 1
    # a non-divisible group below the axis size is a misconfiguration and
    # must fail loudly (as _grid_groups always did), not degrade silently
    with pytest.raises(ValueError):
        cr.CommSpec.from_plan(
            ShardingPlan(("data",), "model", 6, None, 1), group_size=4
        )


def test_reconfigure_hook_returns_same_op_class():
    for op in (
        cr.AllToAll(cr.CommSpec(axis="model", axis_size=4)),
        cr.AllReduce(cr.CommSpec(axis="model", axis_size=4)),
        cr.AllGather(cr.CommSpec(axis="model", axis_size=4), impl="ring"),
        cr.ReduceScatter(cr.CommSpec(axis="model", axis_size=4)),
        cr.Permute(cr.CommSpec(axis="model", axis_size=4)),
    ):
        new = op.reconfigure(dest_perm=np.array([1, 0, 3, 2]))
        assert type(new) is type(op)
        assert new.spec.dest_perm == (1, 0, 3, 2)
        assert op.spec.dest_perm is None  # original untouched
    # AllGather keeps its lowering choice through the hook
    ag = cr.AllGather(cr.CommSpec(axis="model", axis_size=4), impl="flat")
    assert ag.reconfigure(src_perm=[3, 2, 1, 0]).impl == "flat"


def test_device_perm_from_slots():
    # whole-device block swap collapses to a wire perm
    np.testing.assert_array_equal(
        cr.device_perm_from_slots(np.array([2, 3, 0, 1]), 2), [1, 0]
    )
    # intra-device reorder or cross-device slot scatter: no wire perm
    assert cr.device_perm_from_slots(np.array([1, 0, 2, 3]), 2) is None
    assert cr.device_perm_from_slots(np.array([0, 2, 1, 3]), 2) is None


# ---------------------------------------------------------------------------
# single-device degradation (every lowering must be callable without a mesh)
# ---------------------------------------------------------------------------


def test_single_device_ops_are_identity():
    spec = cr.CommSpec()
    x = jnp.arange(24.0).reshape(2, 4, 3)
    ids = jnp.arange(8, dtype=jnp.int32).reshape(2, 4)
    assert (cr.AllToAll(spec)(x) == x).all()
    px, pe = cr.AllToAll(spec).fused(x, ids)
    assert (px == x).all() and (pe == ids).all()
    assert (cr.AllReduce(spec)(x) == x).all()
    assert (cr.AllGather(spec)(x) == x).all()
    # untiled degenerate gather inserts the new dim at the REQUESTED axis
    assert cr.AllGather(spec)(x, axis=1, tiled=False).shape == (2, 1, 4, 3)
    assert (cr.ReduceScatter(spec)(x) == x).all()
    assert (cr.Permute(spec)(x) == x).all()
    # a cost-only reduction spec must refuse to execute, not mis-scale
    with pytest.raises(ValueError):
        cr.AllReduce(cr.CommSpec(axis=None, axis_size=8, group_size=8))(x)


def test_fused_pack_exact_all_dtypes():
    """The packed-lane encoding is exact across each dtype's documented id
    range (and never emits NaN/Inf bit patterns a backend could rewrite)."""
    ids16 = jnp.array([[-1, 0, 7, 255, 256, 2**16 - 2]], dtype=jnp.int32)
    ids32 = jnp.array([[-1, 0, 7, 65535, 2**24 - 2]], dtype=jnp.int32)
    for dt, ids in ((jnp.float32, ids32), (jnp.bfloat16, ids16), (jnp.float16, ids16)):
        lanes = cr._ids_to_lanes(ids, dt)
        assert lanes.dtype == jnp.dtype(dt)
        assert np.isfinite(np.asarray(lanes, np.float64)).all()
        np.testing.assert_array_equal(
            np.asarray(cr._lanes_to_ids(lanes, dt)), np.asarray(ids)
        )


# ---------------------------------------------------------------------------
# bytes-on-link accounting
# ---------------------------------------------------------------------------


def test_alltoall_bytes_flat_vs_hierarchical():
    b = 1024.0
    flat = cr.AllToAll(cr.CommSpec(axis="model", axis_size=8)).bytes_on_link(b)
    assert flat.scale_out == pytest.approx(b * 7 / 8)
    assert flat.scale_up == 0.0
    hier = cr.AllToAll(
        cr.CommSpec(axis="model", axis_size=8, group_size=4)
    ).bytes_on_link(b)
    assert hier.scale_up == pytest.approx(b * 3 / 4)   # stage 1 intra-group
    assert hier.scale_out == pytest.approx(b * 1 / 2)  # stage 2: G=2 groups
    # the delegation moves the scale-out share OFF the contended fabric
    assert hier.scale_out < flat.scale_out
    assert cr.AllToAll(cr.CommSpec()).bytes_on_link(b).total == 0.0


def test_allreduce_bytes_hierarchy_cuts_cross_region():
    b = 4096.0
    flat = cr.AllReduce(cr.CommSpec(axis="data", axis_size=8)).bytes_on_link(b)
    assert flat.cross_region == pytest.approx(2 * b * 7 / 8)
    hier = cr.AllReduce(
        cr.CommSpec(axis="data", axis_size=8, group_size=8,
                    outer_axis="pod", outer_size=16)
    ).bytes_on_link(b)
    # cross-region ring carries 1/inner of the payload (§5.3)
    assert hier.cross_region == pytest.approx(2 * (b / 8) * 15 / 16)
    assert hier.cross_region < flat.cross_region


def test_gather_scatter_permute_bytes():
    b = 512.0
    ag = cr.AllGather(cr.CommSpec(axis="model", axis_size=4)).bytes_on_link(b)
    assert ag.scale_out == pytest.approx(b * 3)  # shard transits each ring hop
    rs = cr.ReduceScatter(cr.CommSpec(axis="model", axis_size=4)).bytes_on_link(b)
    assert rs.scale_out == pytest.approx(b * 3 / 4)
    pm = cr.Permute(cr.CommSpec(axis="model", axis_size=4)).bytes_on_link(b)
    assert pm.scale_out == pytest.approx(b)


def test_uniform_demand_matches_bytes_on_link():
    """Same CommSpec -> same accounting: a uniform inter-server demand built
    from a per-server payload B has row sums equal to the flat op's
    scale-out bytes."""
    servers, b = 8, 1e9
    spec = cr.CommSpec(axis=None, axis_size=servers)
    demand = np.full((servers, servers), b / servers)
    np.fill_diagonal(demand, 0.0)
    link = cr.AllToAll(spec).bytes_on_link(b)
    np.testing.assert_allclose(demand.sum(axis=1), link.total)
    np.testing.assert_allclose(demand.sum(axis=0), link.total)


# ---------------------------------------------------------------------------
# wire re-addressing (the cost-model half of the reconfiguration hook)
# ---------------------------------------------------------------------------


def test_route_demand_permutes_physical_destinations():
    d = np.arange(16.0).reshape(4, 4)
    op = cr.AllToAll(cr.CommSpec(axis=None, axis_size=4)).reconfigure(
        dest_perm=[2, 0, 3, 1]
    )
    np.testing.assert_array_equal(op.route_demand(d), d[:, [2, 0, 3, 1]])
    # identity spec routes nothing
    base = cr.AllToAll(cr.CommSpec(axis=None, axis_size=4))
    assert base.route_demand(d) is d
    with pytest.raises(ValueError):
        op.route_demand(np.zeros((6, 6)))


def test_route_demand_changes_cost_on_solved_circuits():
    """After Algorithm 1 matches circuits to a skewed demand, re-addressing
    the wire chunks away from those circuits must not get cheaper."""
    rng = np.random.default_rng(0)
    demand = rng.random((8, 8)) * 1e8
    demand[0, 5] = 5e9  # hot pair the solver will provision
    np.fill_diagonal(demand, 0.0)
    fab = make_fabric("mixnet", FabricConfig(num_servers=8, link_gbps=100))
    fab.prepare(demand)
    op = cr.AllToAll(cr.CommSpec.from_fabric(fab, 8))
    base = op.cost(fab, demand)
    rotated = op.reconfigure(dest_perm=np.roll(np.arange(8), 1))
    assert rotated.cost(fab, demand) >= base


# ---------------------------------------------------------------------------
# netsim <-> runtime cross-checks (acceptance: one cost model, not two)
# ---------------------------------------------------------------------------


def test_cost_equals_fabric_pricing_for_every_fabric():
    """The op's cost is the fabric-priced completion of the routed demand —
    netsim's phase costs and the runtime agree by construction."""
    model = MIXTRAL_8X7B
    trace = GateTraceGenerator(4, model.num_experts, seed=3)
    demand = trace.device_demand(trace.step()[0], model, 8)
    for name in ("mixnet", "fat-tree", "oversub-fat-tree", "rail-optimized"):
        fab = make_fabric(name, FabricConfig(num_servers=8, link_gbps=400))
        op = cr.AllToAll(cr.CommSpec.from_fabric(fab, 8))
        assert op.cost(fab, demand) == pytest.approx(fab.alltoall_time(demand))
        ar = cr.AllReduce(cr.CommSpec(
            axis=None, axis_size=8, group_size=8, outer_size=fab.cfg.num_servers
        ))
        assert ar.cost(fab, 1e9) == pytest.approx(fab.allreduce_time(1e9))


def test_simmodel_bytes_come_from_commruntime():
    """netsim's SimModel byte sizes are the runtime helpers, not private
    formulas."""
    m = MIXTRAL_8X7B
    assert m.a2a_bytes_total() == cr.ep_alltoall_bytes(
        m.tokens_per_microbatch, m.top_k, m.d_model, m.dtype_bytes
    )
    assert m.dp_gradient_bytes_per_server(8) == cr.dp_gradient_bytes(
        m.param_count(), m.gpus_per_stage * m.pp_degree, 8, m.dtype_bytes
    )
    # and the source keeps no duplicated inline formula
    import inspect

    from repro.core import netsim

    src = inspect.getsource(netsim.SimModel.a2a_bytes_total)
    assert "ep_alltoall_bytes" in src


def test_from_fabric_factorization_follows_topology():
    fab = make_fabric("mixnet", FabricConfig(num_servers=128, gpus_per_server=8))
    s = cr.CommSpec.from_fabric(fab, 16)
    assert s.axis_size == 16 * 8  # region servers x scale-up domain
    assert s.group_size == 8 and s.hierarchical and s.num_groups == 16
    assert s.outer_size == 128


def test_netsim_simulation_unchanged_by_port():
    """The ported costing is bit-compatible with the pre-port fabric-direct
    formulas, checked against independently hand-written references (NOT the
    ported code itself)."""
    from repro.core.netsim import simulate_iteration

    m = dataclasses.replace(MIXTRAL_8X7B, num_blocks=8)
    # byte sizes: the historical inline formulas, written out literally
    assert m.a2a_bytes_total() == (
        m.tokens_per_microbatch * m.top_k * m.d_model * m.dtype_bytes
    )
    per_gpu = m.param_count() / max(m.gpus_per_stage * m.pp_degree, 1)
    assert m.dp_gradient_bytes_per_server(8) == per_gpu * 8 * m.dtype_bytes
    # phase pricing: the DP component of an iteration is exactly half the
    # fabric-priced hierarchical all-reduce of those bytes (the pre-port
    # `0.5 * fabric.allreduce_time(dp_bytes)` expression)
    cfg = FabricConfig(num_servers=16, link_gbps=400)
    fab = make_fabric("mixnet", cfg)
    trace = GateTraceGenerator(m.layers_per_stage, m.num_experts, seed=7)
    res = simulate_iteration(m, fab, trace, num_servers_region=4)
    expected_dp = 0.5 * make_fabric("mixnet", cfg).allreduce_time(
        m.dp_gradient_bytes_per_server(8)
    )
    assert res.dp_allreduce == pytest.approx(expected_dp)
    assert res.a2a > 0
