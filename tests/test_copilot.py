"""MIXNET-COPILOT: fit quality + Fig 19 ordering (COPILOT > unchanged >
random) on synthetic traces with cross-layer structure."""

import numpy as np
import pytest

from repro.core.copilot import (
    CopilotPredictor,
    fit_transition_matrix,
    predict_next_load,
    topk_accuracy,
)
from repro.core.netsim import GateTraceGenerator
from repro.core.traffic import TrafficMonitor

import jax.numpy as jnp


def test_fit_recovers_transition():
    rng = np.random.default_rng(0)
    e = 8
    p_true = rng.dirichlet(np.ones(e) * 0.5, size=e).T  # column-stochastic
    xs = rng.dirichlet(np.ones(e), size=12)
    ys = (p_true @ xs.T).T
    w = np.ones(12)
    p0 = np.full((e, e), 1.0 / e)
    p = np.asarray(
        fit_transition_matrix(jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(w),
                              jnp.asarray(p0), steps=400)
    )
    # columns remain distributions
    assert np.allclose(p.sum(axis=0), 1.0, atol=1e-4)
    assert (p >= -1e-6).all()
    # prediction error small on the training pairs
    pred = (p @ xs.T).T
    assert np.abs(pred - ys).max() < 0.05


def test_fit_matches_scipy_slsqp_objective():
    """Projected-gradient solution is as good as scipy's SLSQP (paper §B.1)."""
    from scipy.optimize import minimize

    rng = np.random.default_rng(1)
    e = 4
    p_true = rng.dirichlet(np.ones(e), size=e).T
    xs = rng.dirichlet(np.ones(e), size=8)
    ys = (p_true @ xs.T).T + rng.normal(0, 0.01, size=(8, e))
    w = np.ones(8) / 8

    def objective(flat):
        p = flat.reshape(e, e)
        pred = (p @ xs.T).T
        return float(np.sum(w[:, None] * (ys - pred) ** 2))

    cons = [
        {"type": "eq", "fun": (lambda f, j=j: f.reshape(e, e)[:, j].sum() - 1.0)}
        for j in range(e)
    ]
    res = minimize(
        objective, np.full(e * e, 1.0 / e), method="SLSQP",
        bounds=[(0, 1)] * (e * e), constraints=cons,
    )
    ours = np.asarray(
        fit_transition_matrix(
            jnp.asarray(xs), jnp.asarray(ys), jnp.asarray(w),
            jnp.asarray(np.full((e, e), 1.0 / e)), steps=500,
        )
    )
    assert objective(ours.reshape(-1)) <= res.fun * 1.25 + 1e-6


def test_batched_refit_matches_looped():
    """The vmapped all-layers refit must reproduce the per-layer loop's
    transitions (atol 1e-5), including ragged windows via zero-weight
    padding."""
    layers, e = 6, 8
    trace = GateTraceGenerator(layers, e, seed=7)
    monitor = TrafficMonitor(layers, e, window=8)
    for _ in range(5):
        loads = trace.step()
        for l in range(layers):
            monitor.record(l, loads[l] * 1000)
        monitor.advance()
    # Ragged windows: layers 0-1 get an extra observation.
    extra = trace.step()
    monitor.record(0, extra[0] * 1000)
    monitor.record(1, extra[1] * 1000)

    looped = CopilotPredictor(layers, e, fit_steps=80, batched_refit=False)
    batched = CopilotPredictor(layers, e, fit_steps=80)
    for _ in range(2):  # two rounds: the second starts from warm fits
        looped.update(monitor)
        batched.update(monitor)
    np.testing.assert_allclose(
        looped.state.transitions, batched.state.transitions, atol=1e-5
    )
    # columns remain distributions in both
    assert np.allclose(batched.state.transitions.sum(axis=1), 1.0, atol=1e-4)


def test_batched_refit_one_layer_pair():
    """Degenerate two-layer model: the batch has exactly one element."""
    layers, e = 2, 4
    trace = GateTraceGenerator(layers, e, seed=2)
    monitor = TrafficMonitor(layers, e)
    for _ in range(4):
        loads = trace.step()
        for l in range(layers):
            monitor.record(l, loads[l] * 100)
        monitor.advance()
    a = CopilotPredictor(layers, e, fit_steps=60, batched_refit=False)
    b = CopilotPredictor(layers, e, fit_steps=60)
    a.update(monitor)
    b.update(monitor)
    np.testing.assert_allclose(a.state.transitions, b.state.transitions, atol=1e-5)


def test_copilot_beats_baselines_fig19():
    layers, e = 6, 16
    trace = GateTraceGenerator(layers, e, seed=3)
    monitor = TrafficMonitor(layers, e, window=8)
    cop = CopilotPredictor(layers, e, fit_steps=120)
    rng = np.random.default_rng(0)

    acc = {"copilot": [], "unchanged": [], "random": []}
    for it in range(30):
        loads = trace.step()
        for l in range(layers):
            monitor.record(l, loads[l] * 1000)
        if it >= 3:
            for l in range(layers - 1):
                k = 4
                pred = cop.predict(l, loads[l])
                acc["copilot"].append(topk_accuracy(pred, loads[l + 1], k))
                acc["unchanged"].append(
                    topk_accuracy(cop.baseline_unchanged(loads[l]), loads[l + 1], k)
                )
                acc["random"].append(
                    topk_accuracy(cop.baseline_random(rng), loads[l + 1], k)
                )
        cop.update(monitor)
        monitor.advance()

    mean = {k: float(np.mean(v)) for k, v in acc.items()}
    assert mean["copilot"] > mean["unchanged"] - 0.02, mean
    assert mean["copilot"] > mean["random"] + 0.05, mean
