"""Training loop integration: loss decreases, checkpoint roundtrip + elastic
restore, gradient compression, optimizer correctness."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SyntheticLM
from repro.models.config import ModelConfig, MoEConfig
from repro.optim import compress
from repro.optim.adamw import AdamWConfig, adamw_update, cosine_lr, init_adamw
from repro.parallel.sharding import make_plan
from repro.train import checkpoint as ckpt
from repro.train.trainer import Trainer, TrainerConfig

PLAN = make_plan(None)


def tiny_moe_cfg():
    return ModelConfig(
        "tiny-moe", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=32, capacity_factor=2.0,
                      backend="mixnet"),
    )


def test_adamw_reduces_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0, total_steps=100)
    params = {"w": jnp.array([3.0, -2.0])}
    state = init_adamw(params, cfg)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}  # d/dw ||w||^2
        params, state, m = adamw_update(params, grads, state, cfg)
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_cosine_schedule_shape():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100)
    assert float(cosine_lr(cfg, jnp.asarray(0))) == pytest.approx(0.0)
    assert float(cosine_lr(cfg, jnp.asarray(10))) == pytest.approx(1.0, abs=0.02)
    assert float(cosine_lr(cfg, jnp.asarray(100))) == pytest.approx(0.0, abs=1e-3)


def test_trainer_loss_decreases_with_reconfig(tmp_path):
    cfg = tiny_moe_cfg()
    opt = AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=60, clip_norm=1.0)
    tcfg = TrainerConfig(
        total_steps=30, ckpt_every=10, ckpt_dir=str(tmp_path), ckpt_async=False,
        reconfig_every=5, reconfig_min_gain=0.01,
    )
    tr = Trainer(cfg, opt, tcfg, PLAN, seed=0)
    data = SyntheticLM(cfg.vocab_size, 16, 4, seed=0)
    log = tr.train(iter(data))
    first = np.mean([m["loss"] for m in log[:5]])
    last = np.mean([m["loss"] for m in log[-5:]])
    assert last < first, (first, last)
    # checkpoints got written
    assert ckpt.latest_step(str(tmp_path)) == 30


def test_trainer_restart_resumes(tmp_path):
    cfg = tiny_moe_cfg()
    opt = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=40)
    tcfg = TrainerConfig(total_steps=10, ckpt_every=5, ckpt_dir=str(tmp_path),
                         ckpt_async=False)
    tr = Trainer(cfg, opt, tcfg, PLAN, seed=0)
    tr.train(iter(SyntheticLM(cfg.vocab_size, 16, 4, seed=0)))
    # new trainer restores at step 10 and continues
    tcfg2 = TrainerConfig(total_steps=12, ckpt_every=0, ckpt_dir=str(tmp_path))
    tr2 = Trainer(cfg, opt, tcfg2, PLAN, seed=0)
    assert tr2.maybe_restore()
    assert tr2.step == 10
    tr2.train(iter(SyntheticLM(cfg.vocab_size, 16, 4, seed=99)))
    assert tr2.step == 12


def test_checkpoint_roundtrip_and_gc(tmp_path):
    tree = {"a": jnp.arange(6).reshape(2, 3), "b": {"c": jnp.ones((4,))}}
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), step, tree, keep=2)
    assert ckpt.latest_step(str(tmp_path)) == 4
    # keep=2 garbage-collected old steps
    dirs = sorted(os.listdir(tmp_path))
    assert dirs == ["step_00000003", "step_00000004"]
    back = ckpt.restore(str(tmp_path), 4, tree)
    np.testing.assert_array_equal(np.asarray(back["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(back["b"]["c"]), np.asarray(tree["b"]["c"]))


def test_int8_compression_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(0), (128,)) * 5
    q, s = compress.int8_encode(x)
    back = compress.int8_decode(q, s)
    assert float(jnp.max(jnp.abs(back - x))) < float(s) * 1.01  # half-step error


def test_error_feedback_converges():
    """With error feedback, the accumulated decode error stays bounded and
    the mean of decoded gradients converges to the true mean."""
    rng = np.random.default_rng(0)
    true = jnp.asarray(rng.normal(size=(64,)) * 0.1)
    residual = jnp.zeros((64,))
    total = jnp.zeros((64,))
    n = 50
    codec = lambda t: compress.int8_decode(*compress.int8_encode(t))
    for _ in range(n):
        decoded, residual = compress.error_feedback_update(true, residual, codec)
        total = total + decoded
    err = float(jnp.max(jnp.abs(total / n - true)))
    assert err < 5e-3


COMPRESSED_PSUM = """
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from repro.optim.compress import compressed_psum
from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.parallel.sharding import shard_map as _compat_shard_map
mesh = _compat_make_mesh((8,), ('data',))
x = jax.random.normal(jax.random.PRNGKey(0), (8 * 4, 16))
exact = _compat_shard_map(lambda v: jax.lax.psum(v, 'data'), mesh=mesh,
                      in_specs=P('data'), out_specs=P('data'))(x)
approx = _compat_shard_map(lambda v: compressed_psum(v, 'data'), mesh=mesh,
                       in_specs=P('data'), out_specs=P('data'))(x)
rel = float(jnp.max(jnp.abs(exact - approx)) / (jnp.max(jnp.abs(exact)) + 1e-9))
assert rel < 0.05, rel
print('COMPRESSED_PSUM_OK')
"""


def test_compressed_psum_multidevice(multidevice):
    out = multidevice(COMPRESSED_PSUM, devices=8)
    assert "COMPRESSED_PSUM_OK" in out


RUNTIME_DP = """
import jax, jax.numpy as jnp, numpy as np
from repro.data.pipeline import SyntheticLM
from repro.models.config import ModelConfig, MoEConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan
from repro.train.train_step import init_all, make_train_step
from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.launch.mesh import use_mesh as _compat_use_mesh

mesh = _compat_make_mesh((8,), ('data',))
# fsdp=False: the runtime mode replicates params inside its shard_map and
# rejects ZeRO-3 plans (both steps use the same plan so they are comparable).
plan = make_plan(mesh, fsdp=False)
# Aux-loss coefficients zeroed: the runtime mode evaluates balance/z per
# shard (GShard per-group semantics), so only the CE path is bit-comparable
# against XLA's whole-batch reduction.
cfg = ModelConfig('tiny-moe', 'moe', 2, 32, 4, 2, 0, 64, dtype='float32',
                  remat='none',
                  moe=MoEConfig(num_experts=4, top_k=2, d_ff=32,
                                capacity_factor=2.0, backend='einsum',
                                balance_loss=0.0, router_z_loss=0.0))
opt = AdamWConfig(lr=1e-3)
params, specs, opt_state = init_all(jax.random.PRNGKey(0), cfg, plan, opt)
import copy
opt_state2 = jax.tree.map(lambda a: a, opt_state)
data = SyntheticLM(cfg.vocab_size, 16, 8, seed=0)
b = next(data)
batch = {'tokens': jnp.asarray(b.tokens), 'labels': jnp.asarray(b.labels)}
with _compat_use_mesh(mesh):
    auto_step = jax.jit(make_train_step(cfg, plan, opt, mesh=mesh))
    rt_step = jax.jit(make_train_step(cfg, plan, opt, mesh=mesh, dp_comm='runtime'))
    pa, oa, ma = auto_step(params, opt_state, batch)
    pr, orr, mr = rt_step(params, opt_state2, batch)
# same loss, same telemetry, same updated params — the runtime's explicit
# hierarchical all-reduce IS the gradient reduction
np.testing.assert_allclose(float(ma['loss']), float(mr['loss']), rtol=1e-5)
np.testing.assert_allclose(float(ma['ce']), float(mr['ce']), rtol=1e-5)
np.testing.assert_allclose(np.asarray(ma['expert_load']),
                           np.asarray(mr['expert_load']), rtol=1e-5, atol=1e-5)
for a, r in zip(jax.tree.leaves(pa), jax.tree.leaves(pr)):
    np.testing.assert_allclose(np.asarray(a, np.float64), np.asarray(r, np.float64),
                               rtol=5e-4, atol=1e-5)

# misconfigurations must be rejected, not silently fall back
try:
    make_train_step(cfg, make_plan(None), opt, mesh=None, dp_comm='runtime')
    raise SystemExit('expected ValueError (no mesh)')
except ValueError:
    pass
try:
    make_train_step(cfg, make_plan(mesh), opt, mesh=mesh, dp_comm='runtime')
    raise SystemExit('expected ValueError (fsdp plan would be un-sharded)')
except ValueError:
    pass
print('RUNTIME_DP_OK')
"""


def test_runtime_dp_grad_reduce_matches_auto(multidevice):
    """dp_comm='runtime': explicit CommRuntime hierarchical all-reduce of
    per-shard gradients reproduces the XLA-auto pjit step."""
    out = multidevice(RUNTIME_DP, devices=8, timeout=900)
    assert "RUNTIME_DP_OK" in out


def test_trainer_per_layer_reconfig_distinct_perms():
    """Two layers with different hot-expert pairs must receive *different*
    expert permutations (the per-layer decisions the old trainer averaged
    into one global perm), and training must continue through them."""
    from repro.core.controlplane import ControlPlane

    cfg = ModelConfig(
        "tiny-moe8", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32, capacity_factor=2.0,
                      backend="mixnet"),
    )
    opt = AdamWConfig(lr=1e-3)
    tcfg = TrainerConfig(total_steps=4, reconfig_every=1, reconfig_min_gain=0.01)
    tr = Trainer(cfg, opt, tcfg, PLAN, seed=0)
    reps = tr.controlplane.num_layers
    assert reps == 2
    # Pretend a 4-device EP region (experts_per_device=2) so placement has
    # freedom; the weight-permute path is identical regardless of sharding.
    tr.controlplane = ControlPlane(
        num_layers=reps, num_experts=cfg.moe.num_experts, num_devices=4,
        use_copilot=False, min_gain_fraction=0.01,
    )
    loads = np.array([
        [30.0, 30.0, 1.0, 1.0, 1.0, 1.0, 1.0, 1.0],
        [1.0, 1.0, 1.0, 1.0, 1.0, 1.0, 30.0, 30.0],
    ])
    tr.step = tcfg.reconfig_every  # align the modulo so planning runs now
    tr._reconfigure_step(loads)
    stack = np.asarray(tr.expert_perm)
    assert (stack[0] != np.arange(8)).any(), stack  # layer 0 reconfigured
    assert (stack[0] != stack[1]).any(), stack  # and differently from layer 1
    assert tr.reconfig_count >= 2
    # training continues with distinct per-layer perms threaded to the router
    log = tr.train(iter(SyntheticLM(cfg.vocab_size, 16, 4, seed=0)))
    assert np.isfinite([float(m["loss"]) for m in log]).all()


def test_trainer_straggler_watchdog():
    cfg = tiny_moe_cfg()
    opt = AdamWConfig(lr=1e-3)
    tr = Trainer(cfg, opt, TrainerConfig(total_steps=3), PLAN, seed=0)
    tr.train(iter(SyntheticLM(cfg.vocab_size, 16, 4, seed=0)))
    assert tr._ema_step_time is not None and tr._ema_step_time > 0
