"""Pipeline parallelism: GPipe schedule over a stage axis == sequential
application of the stages, forward and backward (8 fake devices)."""

PIPE = """
import jax, jax.numpy as jnp, numpy as np
from repro.train.pipeline import pipeline_apply

S, M, MB, D = 4, 6, 2, 8
from repro.launch.mesh import make_mesh as _compat_make_mesh
mesh = _compat_make_mesh((S,), ('stage',))
key = jax.random.PRNGKey(0)
params = {'w': jax.random.normal(key, (S, D, D)) * 0.3,
          'b': jax.random.normal(key, (S, D)) * 0.1}
mbs = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))

def stage_fn(p, x):
    return jnp.tanh(x @ p['w'] + p['b'])

out = pipeline_apply(stage_fn, params, mbs, mesh)

# sequential reference
ref = mbs
for si in range(S):
    p = {'w': params['w'][si], 'b': params['b'][si]}
    ref = jax.vmap(lambda x: stage_fn(p, x))(ref)
np.testing.assert_allclose(np.asarray(out), np.asarray(ref), rtol=1e-5, atol=1e-5)

# gradients flow through the reverse pipeline
def loss_pipe(params):
    return jnp.sum(pipeline_apply(stage_fn, params, mbs, mesh) ** 2)
def loss_seq(params):
    y = mbs
    for si in range(S):
        p = {'w': params['w'][si], 'b': params['b'][si]}
        y = jax.vmap(lambda x: stage_fn(p, x))(y)
    return jnp.sum(y ** 2)
g_pipe = jax.grad(loss_pipe)(params)
g_seq = jax.grad(loss_seq)(params)
for k in ('w', 'b'):
    np.testing.assert_allclose(np.asarray(g_pipe[k]), np.asarray(g_seq[k]),
                               rtol=1e-4, atol=1e-4)
print('PIPELINE_OK')
"""


def test_pipeline_matches_sequential(multidevice):
    out = multidevice(PIPE, devices=8, timeout=900)
    assert "PIPELINE_OK" in out


def test_num_ticks():
    from repro.train.pipeline import num_ticks

    s = 4
    for m in (1, s - 1, s, 3 * s):
        assert num_ticks(m, s) == m + s - 1
    assert num_ticks(1, 1) == 1
    assert num_ticks(7, 1) == 7


# Ragged microbatch counts (M < S included): drain ticks feed zeros, never a
# stale re-fed microbatch, and the output slice stays exact for every M.
RAGGED = """
import jax, jax.numpy as jnp, numpy as np
from repro.train.pipeline import pipeline_apply
from repro.launch.mesh import make_mesh as _compat_make_mesh

S, MB, D = 4, 2, 8
mesh = _compat_make_mesh((S,), ('stage',))
key = jax.random.PRNGKey(0)
params = {'w': jax.random.normal(key, (S, D, D)) * 0.3,
          'b': jax.random.normal(key, (S, D)) * 0.1}

def stage_fn(p, x):
    return jnp.tanh(x @ p['w'] + p['b'])

for M in (1, S - 1, S, 3 * S):
    mbs = jax.random.normal(jax.random.PRNGKey(M), (M, MB, D))
    out = pipeline_apply(stage_fn, params, mbs, mesh)
    ref = mbs
    for si in range(S):
        p = {'w': params['w'][si], 'b': params['b'][si]}
        ref = jax.vmap(lambda x: stage_fn(p, x))(ref)
    assert out.shape == ref.shape, (M, out.shape, ref.shape)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)
    # grads through the ragged schedule stay finite and match sequential
    g_pipe = jax.grad(lambda p: jnp.sum(pipeline_apply(stage_fn, p, mbs, mesh) ** 2))(params)
    g_seq = jax.grad(lambda p: jnp.sum(
        jax.vmap(lambda x: stage_fn({'w': p['w'][3], 'b': p['b'][3]},
                 stage_fn({'w': p['w'][2], 'b': p['b'][2]},
                 stage_fn({'w': p['w'][1], 'b': p['b'][1]},
                 stage_fn({'w': p['w'][0], 'b': p['b'][0]}, x)))))(mbs) ** 2))(params)
    for k in ('w', 'b'):
        np.testing.assert_allclose(np.asarray(g_pipe[k]), np.asarray(g_seq[k]),
                                   rtol=1e-4, atol=1e-4)
print('RAGGED_OK')
"""


def test_pipeline_ragged_microbatches(multidevice):
    out = multidevice(RAGGED, devices=8, timeout=900)
    assert "RAGGED_OK" in out
