"""MoE dispatch backends: einsum (GShard) vs mixnet (hierarchical shard_map
a2a) equivalence — single device and 8-device subprocess, with and without
virtual experts and runtime placement permutations."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.models import moe as moe_mod
from repro.models.config import ModelConfig, MoEConfig
from repro.parallel.sharding import make_plan, virtual_experts

KEY = jax.random.PRNGKey(0)
PLAN = make_plan(None)


def make_cfg(num_experts=4, top_k=2, cf=4.0, shared=0, dispatch="dropless"):
    return ModelConfig(
        "t", "moe", 2, 32, 4, 2, 64, 128, dtype="float32",
        moe=MoEConfig(num_experts=num_experts, top_k=top_k, d_ff=48,
                      capacity_factor=cf, num_shared_experts=shared, a2a_group=2,
                      dispatch=dispatch),
    )


def test_backends_agree_single_device():
    cfg = make_cfg()
    params, _ = moe_mod.init_moe(KEY, cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    oe, se = moe_mod.moe_apply(params, x, cfg, PLAN, backend="einsum")
    om, sm = moe_mod.moe_apply(params, x, cfg, PLAN, backend="mixnet")
    assert float(jnp.max(jnp.abs(oe - om))) < 1e-5
    np.testing.assert_allclose(np.asarray(se.expert_load), np.asarray(sm.expert_load))


def test_shared_experts_added():
    cfg = make_cfg(shared=2)
    params, _ = moe_mod.init_moe(KEY, cfg, PLAN)
    assert "shared" in params
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    out, _ = moe_mod.moe_apply(params, x, cfg, PLAN, backend="einsum")
    # zeroing shared weights changes the output -> they participate
    p2 = dict(params)
    p2["shared"] = jax.tree.map(jnp.zeros_like, params["shared"])
    out2, _ = moe_mod.moe_apply(p2, x, cfg, PLAN, backend="einsum")
    assert float(jnp.max(jnp.abs(out - out2))) > 1e-4


def test_capacity_drops_tokens():
    cfg = make_cfg(cf=0.25, dispatch="capacity")  # deliberately tight capacity
    params, _ = moe_mod.init_moe(KEY, cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    _, stats = moe_mod.moe_apply(params, x, cfg, PLAN, backend="einsum")
    assert float(stats.dropped_fraction) > 0.0


def test_dropless_never_drops():
    """Default dispatch is dropless: even an absurd capacity factor drops
    nothing on any backend."""
    cfg = make_cfg(cf=0.01)
    params, _ = moe_mod.init_moe(KEY, cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 32, 32))
    for backend in ("einsum", "mixnet"):
        _, stats = moe_mod.moe_apply(params, x, cfg, PLAN, backend=backend)
        assert float(stats.dropped_fraction) == 0.0, backend


@given(seed=st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_router_losses_bounded(seed):
    logits = jax.random.normal(jax.random.PRNGKey(seed), (64, 8)) * 3
    _, idx = jax.lax.top_k(logits, 2)
    bal, z = moe_mod.router_losses(logits, idx, 8)
    # balance loss >= 1 (perfectly balanced == 1), z-loss >= 0
    assert float(bal) >= 0.99
    assert float(z) >= 0.0


def test_virtual_experts_factoring():
    assert virtual_experts(8, 16) == (16, 2)
    assert virtual_experts(160, 16) == (160, 1)
    assert virtual_experts(4, 1) == (4, 1)
    with pytest.raises(ValueError):
        virtual_experts(3, 16)


MULTIDEV = """
import jax, jax.numpy as jnp, numpy as np
from repro.models.config import ModelConfig, MoEConfig
from repro.models import moe as moe_mod
from repro.parallel.sharding import make_plan

from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.launch.mesh import use_mesh as _compat_use_mesh
mesh = _compat_make_mesh((2, 4), ('data', 'model'))
plan = make_plan(mesh)
plan1 = make_plan(None)

# E=8 over model=4 (2 local experts/device)
cfg = ModelConfig('t', 'moe', 2, 32, 4, 2, 64, 128, dtype='float32',
                  moe=MoEConfig(num_experts=8, top_k=2, d_ff=48, capacity_factor=8.0, a2a_group=2))
params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, plan)
params1, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, plan1)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))
ref, ref_st = moe_mod.moe_apply(params1, x, cfg, plan1, backend='einsum')
with _compat_use_mesh(mesh):
    out, st = jax.jit(lambda p, v: moe_mod.moe_apply(p, v, cfg, plan, mesh=mesh, backend='mixnet'))(params, x)
assert float(jnp.max(jnp.abs(ref - out))) < 1e-5
np.testing.assert_allclose(np.asarray(ref_st.expert_load), np.asarray(st.expert_load))

# virtual experts: E=2 over model=4 (r=2); einsum vs mixnet on same mesh
cfg2 = ModelConfig('t2', 'moe', 2, 32, 4, 2, 64, 128, dtype='float32',
                   moe=MoEConfig(num_experts=2, top_k=1, d_ff=48, capacity_factor=8.0, a2a_group=2))
params2, _ = moe_mod.init_moe(jax.random.PRNGKey(2), cfg2, plan)
with _compat_use_mesh(mesh):
    o_m, _ = jax.jit(lambda p, v: moe_mod.moe_apply(p, v, cfg2, plan, mesh=mesh, backend='mixnet'))(params2, x)
    o_e, _ = jax.jit(lambda p, v: moe_mod.moe_apply(p, v, cfg2, plan, mesh=mesh, backend='einsum'))(params2, x)
assert float(jnp.max(jnp.abs(o_m - o_e))) < 1e-5

# runtime placement permutation preserves the math (weights permuted + perm passed)
from repro.core.placement import apply_placement, inverse_permutation
ev = 8
perm = np.array([3,1,4,0,6,2,7,5], dtype=np.int32)
pp = dict(params)
pp_moe = {k: (apply_placement(v, perm) if k in ('w_in','w_gate','w_out') else v)
          for k, v in params.items()}
with _compat_use_mesh(mesh):
    out_p, _ = jax.jit(lambda p, v: moe_mod.moe_apply(p, v, cfg, plan, mesh=mesh,
                       backend='mixnet', expert_perm=jnp.asarray(perm)))(pp_moe, x)
assert float(jnp.max(jnp.abs(out_p - ref))) < 1e-5, 'placement permutation changed the math'
print('MOE_MULTIDEV_OK')
"""


def test_moe_multidevice(multidevice):
    out = multidevice(MULTIDEV, devices=8, timeout=900)
    assert "MOE_MULTIDEV_OK" in out


FUSED_REGRESSION = """
import dataclasses
import jax, jax.numpy as jnp, numpy as np
from repro.models.config import ModelConfig, MoEConfig
from repro.models import moe as moe_mod
from repro.parallel.sharding import make_plan
from repro.launch.mesh import make_mesh as _compat_make_mesh
from repro.launch.mesh import use_mesh as _compat_use_mesh

mesh = _compat_make_mesh((2, 4), ('data', 'model'))
plan = make_plan(mesh)

# The fused payload+gate a2a must be BIT-identical to the unfused baseline,
# in both dispatch modes and payload dtypes.
for dtype in ('float32', 'bfloat16'):
    for dispatch in ('dropless', 'capacity'):
        cfg = ModelConfig('t', 'moe', 2, 32, 4, 2, 64, 128, dtype=dtype,
                          moe=MoEConfig(num_experts=8, top_k=2, d_ff=48,
                                        capacity_factor=2.0, a2a_group=2,
                                        dispatch=dispatch))
        cfg_u = dataclasses.replace(
            cfg, moe=dataclasses.replace(cfg.moe, a2a_fuse=False))
        params, _ = moe_mod.init_moe(jax.random.PRNGKey(0), cfg, plan)
        x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32)).astype(cfg.dtype)
        with _compat_use_mesh(mesh):
            out_f, st_f = jax.jit(lambda p, v: moe_mod.moe_apply(
                p, v, cfg, plan, mesh=mesh, backend='mixnet'))(params, x)
            out_u, st_u = jax.jit(lambda p, v: moe_mod.moe_apply(
                p, v, cfg_u, plan, mesh=mesh, backend='mixnet'))(params, x)
        np.testing.assert_array_equal(np.asarray(out_f), np.asarray(out_u)), (dtype, dispatch)
        np.testing.assert_array_equal(
            np.asarray(st_f.expert_load), np.asarray(st_u.expert_load))
        assert float(st_f.dropped_fraction) == float(st_u.dropped_fraction)
print('FUSED_MOE_OK')
"""


def test_moe_fused_a2a_bit_identical_to_unfused(multidevice):
    """Satellite: the mixnet backend's packed payload+gate transfer is a pure
    wire-level fusion — zero numeric effect."""
    out = multidevice(FUSED_REGRESSION, devices=8, timeout=900)
    assert "FUSED_MOE_OK" in out


def test_dense_decode_matches_sparse_backends():
    """The auto-selected S=1 dense weight-stationary decode path computes the
    same function as the sparse dispatch backends (§Perf)."""
    cfg = make_cfg(num_experts=8, top_k=2, cf=8.0)
    params, _ = moe_mod.init_moe(KEY, cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 1, 32))
    out_dense, st_d = moe_mod.moe_apply(params, x, cfg, PLAN, backend="mixnet")
    out_einsum, st_e = moe_mod.moe_apply(params, x, cfg, PLAN, backend="einsum")
    assert float(jnp.max(jnp.abs(out_dense - out_einsum))) < 1e-5
    np.testing.assert_allclose(
        np.asarray(st_d.expert_load), np.asarray(st_e.expert_load)
    )
