"""Shared routing core (repro.models.routing): sort-based plan invariants,
backend parity through the one routing engine (dropless + capacity), the
dense-decode ``expert_perm`` regression, and the two-stage drop telemetry."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.placement import apply_placement
from repro.models import moe as moe_mod
from repro.models import routing
from repro.models.config import ModelConfig, MoEConfig
from repro.parallel.sharding import make_plan

KEY = jax.random.PRNGKey(0)
PLAN = make_plan(None)
BACKENDS = ("einsum", "mixnet", "dense_decode")


def make_cfg(num_experts=8, top_k=2, cf=8.0, dispatch="dropless"):
    return ModelConfig(
        "t", "moe", 2, 32, 4, 2, 64, 128, dtype="float32",
        moe=MoEConfig(num_experts=num_experts, top_k=top_k, d_ff=48,
                      capacity_factor=cf, a2a_group=2, dispatch=dispatch),
    )


# ---------------------------------------------------------------------------
# plan-level invariants
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2])
@pytest.mark.parametrize("buckets,n", [(4, 64), (8, 17), (16, 256)])
def test_bucket_ranks_match_cumsum_semantics(seed, buckets, n):
    """Stable argsort ranks == the historical one_hot+cumsum ranks."""
    dest = jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, buckets)
    rank, counts = routing.bucket_ranks(dest, buckets)
    oh = jax.nn.one_hot(dest, buckets, dtype=jnp.int32)
    pos = jnp.cumsum(oh, axis=0) - oh
    expect = jnp.sum(pos * oh, axis=1)
    assert bool(jnp.all(rank == expect))
    np.testing.assert_array_equal(
        np.asarray(counts), np.bincount(np.asarray(dest), minlength=buckets)
    )


@pytest.mark.parametrize("seed", [0, 3])
@pytest.mark.parametrize("buckets,n,block", [(4, 64, 8), (8, 100, 16), (3, 9, 4)])
def test_dropless_plan_places_every_choice(seed, buckets, n, block):
    dest = jax.random.randint(jax.random.PRNGKey(seed), (n,), 0, buckets)
    rank, counts = routing.bucket_ranks(dest, buckets)
    plan = routing.dropless_plan(dest, rank, counts, None, buckets, block)
    slot = np.asarray(plan.slot)
    src = np.asarray(plan.src)
    be = np.asarray(plan.block_experts)
    # dropless: every choice placed, in a unique row, and invertible
    assert (slot >= 0).all() and int(plan.kept) == n
    assert len(set(slot.tolist())) == n
    assert plan.num_rows % block == 0 and (src >= -1).all()
    for i in range(n):
        assert src[slot[i]] == i
        # the owning block's expert matches the choice's destination
        assert be[slot[i] // block] == dest[i]
    # empty rows are marked empty
    assert (np.delete(src, slot) == -1).all()


def test_capacity_plan_drops_overflow_in_order():
    dest = jnp.array([0, 0, 0, 1, 0, 1], dtype=jnp.int32)
    rank, _ = routing.bucket_ranks(dest, 2)
    plan = routing.capacity_plan(dest, rank, None, 2, 2)
    # first-come (token-order) keeps, like the historical cumsum ranks
    np.testing.assert_array_equal(
        np.asarray(plan.slot), np.array([0, 1, -1, 2, -1, 3])
    )
    assert int(plan.kept) == 4


# ---------------------------------------------------------------------------
# backend parity through the shared core
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("dispatch", ["dropless", "capacity"])
@pytest.mark.parametrize("top_k", [1, 2])
def test_backend_parity_seeded_sweep(dispatch, top_k):
    """einsum, mixnet and dense_decode agree through the shared routing core
    (generous capacity in capacity mode so no backend drops)."""
    for seed in (0, 1, 2):
        cfg = make_cfg(top_k=top_k, dispatch=dispatch)
        params, _ = moe_mod.init_moe(jax.random.PRNGKey(seed), cfg, PLAN)
        x = jax.random.normal(jax.random.PRNGKey(seed + 100), (2, 16, 32))
        outs, loads = {}, {}
        for backend in BACKENDS:
            out, st = moe_mod.moe_apply(params, x, cfg, PLAN, backend=backend)
            outs[backend], loads[backend] = out, st.expert_load
        for backend in BACKENDS[1:]:
            err = float(jnp.max(jnp.abs(outs["einsum"] - outs[backend])))
            assert err < 1e-5, (backend, dispatch, top_k, seed, err)
            np.testing.assert_allclose(
                np.asarray(loads["einsum"]), np.asarray(loads[backend])
            )


@pytest.mark.parametrize("dispatch", ["dropless", "capacity"])
def test_backend_parity_with_expert_perm(dispatch):
    """Non-identity expert->slot permutation (permuted weights + perm passed)
    preserves the math on every backend."""
    cfg = make_cfg(dispatch=dispatch)
    params, _ = moe_mod.init_moe(KEY, cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 8, 32))
    perm = jnp.array([3, 1, 4, 0, 6, 2, 7, 5], dtype=jnp.int32)
    permuted = {
        k: (apply_placement(v, np.asarray(perm)) if k in ("w_in", "w_gate", "w_out") else v)
        for k, v in params.items()
    }
    for backend in BACKENDS:
        base, _ = moe_mod.moe_apply(params, x, cfg, PLAN, backend=backend)
        out, _ = moe_mod.moe_apply(
            permuted, x, cfg, PLAN, backend=backend, expert_perm=perm
        )
        err = float(jnp.max(jnp.abs(base - out)))
        assert err < 1e-5, (backend, dispatch, err)


def test_dropless_invariant_exact_combine():
    """Dropless = exact: every token contributes exactly top_k·r combine
    terms, so the MoE output equals the brute-force per-token gate-weighted
    expert sum."""
    cfg = make_cfg(num_experts=4, top_k=2)
    params, _ = moe_mod.init_moe(KEY, cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 16, 32))
    xt = x.reshape(-1, 32)
    logits = xt @ params["router"]
    w, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), 2)
    w = w / w.sum(-1, keepdims=True)

    def one_expert(e, tok):
        h = tok @ params["w_in"][e]
        g = jax.nn.silu(tok @ params["w_gate"][e])
        return (g * h) @ params["w_out"][e]

    expect = jnp.stack([
        sum(w[t, k] * one_expert(idx[t, k], xt[t]) for k in range(2))
        for t in range(xt.shape[0])
    ]).reshape(x.shape)
    for backend in BACKENDS:
        out, stats = moe_mod.moe_apply(params, x, cfg, PLAN, backend=backend)
        assert float(jnp.max(jnp.abs(out - expect))) < 1e-5, backend
        assert float(stats.dropped_fraction) == 0.0, backend


# ---------------------------------------------------------------------------
# satellite regressions
# ---------------------------------------------------------------------------


def test_dense_decode_honors_expert_perm():
    """Regression: decode after a runtime reconfiguration (physically
    permuted expert weights + the layer's perm) must match the
    pre-reconfiguration output — dense_decode used to ignore the perm."""
    cfg = make_cfg(num_experts=8, top_k=2)
    params, _ = moe_mod.init_moe(KEY, cfg, PLAN)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, 1, 32))  # S=1 decode
    perm = jnp.array([5, 0, 3, 7, 2, 6, 1, 4], dtype=jnp.int32)
    permuted = {
        k: (apply_placement(v, np.asarray(perm)) if k in ("w_in", "w_gate", "w_out") else v)
        for k, v in params.items()
    }
    base, _ = moe_mod.moe_apply(params, x, cfg, PLAN, backend="dense_decode")
    # via the auto decode switch (mixnet backend, S=1) AND explicitly
    for backend in ("mixnet", "dense_decode"):
        out, _ = moe_mod.moe_apply(
            permuted, x, cfg, PLAN, backend=backend, expert_perm=perm
        )
        assert float(jnp.max(jnp.abs(base - out))) < 1e-5, backend


def test_mixnet_drop_telemetry_counts_pack_stage():
    """Regression: stage-2 (pack-by-expert) drops must show up in
    ``dropped_fraction``.  A heavily skewed router overflows the per-expert
    pack buffers while the stage-1 device send buffer (single device) never
    drops — the old telemetry reported 0 here."""
    cfg = make_cfg(num_experts=4, top_k=1, cf=1.0, dispatch="capacity")
    params, _ = moe_mod.init_moe(KEY, cfg, PLAN)
    # Bias the router so (almost) all tokens pick expert 0.
    params = dict(params)
    params["router"] = params["router"].at[:, 0].set(50.0)
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 32, 32))
    _, stats = moe_mod.moe_apply(params, x, cfg, PLAN, backend="mixnet")
    assert float(stats.dropped_fraction) > 0.2
    # and the einsum backend agrees about the realized loss
    _, st_e = moe_mod.moe_apply(params, x, cfg, PLAN, backend="einsum")
    assert abs(float(stats.dropped_fraction) - float(st_e.dropped_fraction)) < 0.26


# ---------------------------------------------------------------------------
# multi-device: virtual experts (r > 1) + perm through the shared core
# ---------------------------------------------------------------------------


MULTIDEV = """
import jax, jax.numpy as jnp, numpy as np, dataclasses
from repro.models.config import ModelConfig, MoEConfig
from repro.models import moe as moe_mod
from repro.parallel.sharding import make_plan
from repro.core.placement import apply_placement

from repro.launch.mesh import make_mesh as _mk, use_mesh as _um
mesh = _mk((2, 4), ('data', 'model'))
plan = make_plan(mesh)
plan1 = make_plan(None)
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, 32))

for dispatch in ('dropless', 'capacity'):
    # virtual experts: E=2 over model=4 (r=2), top_k=1
    cfg = ModelConfig('t', 'moe', 2, 32, 4, 2, 64, 128, dtype='float32',
                      moe=MoEConfig(num_experts=2, top_k=1, d_ff=48,
                                    capacity_factor=8.0, a2a_group=2,
                                    dispatch=dispatch))
    params, _ = moe_mod.init_moe(jax.random.PRNGKey(2), cfg, plan)
    with _um(mesh):
        o_m, st_m = jax.jit(lambda p, v: moe_mod.moe_apply(
            p, v, cfg, plan, mesh=mesh, backend='mixnet'))(params, x)
        o_e, st_e = jax.jit(lambda p, v: moe_mod.moe_apply(
            p, v, cfg, plan, mesh=mesh, backend='einsum'))(params, x)
    assert float(jnp.max(jnp.abs(o_m - o_e))) < 1e-5, dispatch
    np.testing.assert_allclose(np.asarray(st_m.expert_load),
                               np.asarray(st_e.expert_load))

    # r=2 + non-identity perm over the 4 virtual slots
    perm = np.array([2, 0, 3, 1], dtype=np.int32)
    pp = {k: (apply_placement(v, perm) if k in ('w_in', 'w_gate', 'w_out') else v)
          for k, v in params.items()}
    with _um(mesh):
        o_p, _ = jax.jit(lambda p, v: moe_mod.moe_apply(
            p, v, cfg, plan, mesh=mesh, backend='mixnet',
            expert_perm=jnp.asarray(perm)))(pp, x)
    assert float(jnp.max(jnp.abs(o_p - o_m))) < 1e-5, dispatch
print('ROUTING_MULTIDEV_OK')
"""


def test_routing_multidevice_virtual_experts(multidevice):
    out = multidevice(MULTIDEV, devices=8, timeout=900)
    assert "ROUTING_MULTIDEV_OK" in out
