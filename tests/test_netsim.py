"""Flow-level simulator + cost model properties, anchored to the paper's
headline claims (validated numerically in benchmarks; sanity-tested here)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.configs.paper_models import MIXTRAL_8X7B, SIM_MODELS
from repro.core import cost as costm
from repro.core.fabric import FabricConfig, make_fabric
from repro.core.netsim import GateTraceGenerator, SimModel, simulate_training


def mean_iter(model, fabric_name, gbps, iters=4, servers=128, **cfg_kw):
    cfg = FabricConfig(num_servers=servers, link_gbps=gbps, **cfg_kw)
    fab = make_fabric(fabric_name, cfg)
    res = simulate_training(
        model, fab, iterations=iters, use_copilot=(fabric_name == "mixnet")
    )
    return float(np.mean([r.total for r in res[1:]]))


def test_more_bandwidth_never_slower():
    t100 = mean_iter(MIXTRAL_8X7B, "mixnet", 100)
    t400 = mean_iter(MIXTRAL_8X7B, "mixnet", 400)
    assert t400 <= t100 * 1.001


def test_mixnet_close_to_fat_tree_and_beats_oversub():
    tm = mean_iter(MIXTRAL_8X7B, "mixnet", 400)
    tf = mean_iter(MIXTRAL_8X7B, "fat-tree", 400)
    to = mean_iter(MIXTRAL_8X7B, "oversub-fat-tree", 400)
    assert tm <= tf * 1.25  # "comparable to non-blocking fat-tree" (§7.3)
    assert tm < to  # outperforms the over-subscribed fabric


def test_mixnet_beats_topoopt():
    """§7.3: MixNet outperforms TopoOpt's static topology."""
    tm = mean_iter(MIXTRAL_8X7B, "mixnet", 100, iters=6)
    tt = mean_iter(MIXTRAL_8X7B, "topoopt", 100, iters=6)
    assert tt / tm > 1.1


def test_cost_efficiency_headline():
    """Fig 13: MixNet cost-efficiency vs fat-tree grows with link bandwidth
    and clears 1.2x at 100G / 1.9x at 400G for Mixtral 8x7B."""
    ratios = {}
    for gbps in (100, 400):
        tm = mean_iter(MIXTRAL_8X7B, "mixnet", gbps, iters=5)
        tf = mean_iter(MIXTRAL_8X7B, "fat-tree", gbps, iters=5)
        cm = costm.fabric_cost("mixnet", 128, gbps)
        cf = costm.fabric_cost("fat-tree", 128, gbps)
        ratios[gbps] = costm.cost_efficiency(tm, cm) / costm.cost_efficiency(tf, cf)
    assert ratios[100] > 1.2, ratios
    assert ratios[400] > 1.9, ratios
    assert ratios[400] > ratios[100]


def test_reconfig_latency_cliff_fig28():
    """25 ms OCS is hidden; second-scale reconfiguration degrades."""
    fast = mean_iter(MIXTRAL_8X7B, "mixnet", 400, reconfig_delay_s=0.025)
    micro = mean_iter(MIXTRAL_8X7B, "mixnet", 400, reconfig_delay_s=1e-5)
    slow = mean_iter(MIXTRAL_8X7B, "mixnet", 400, reconfig_delay_s=10.0)
    assert fast <= micro * 1.1  # ms-scale already fully hidden
    assert slow > fast * 1.5  # the Fig 28 cliff


def test_failure_resilience_fig14():
    """OCS link failure on one server costs only a few percent (EPS fallback)."""
    cfg = FabricConfig(num_servers=128, link_gbps=400)
    fab = make_fabric("mixnet", cfg)
    healthy = simulate_training(MIXTRAL_8X7B, fab, iterations=4)
    t_healthy = float(np.mean([r.total for r in healthy[1:]]))
    fab.fail_server_ocs(0)
    failed = simulate_training(MIXTRAL_8X7B, fab, iterations=4, seed=1)
    t_failed = float(np.mean([r.total for r in failed[1:]]))
    assert t_failed < t_healthy * 1.35
    assert t_failed >= t_healthy * 0.95


@given(seed=st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_trace_generator_is_valid_distribution(seed):
    g = GateTraceGenerator(4, 16, seed=seed)
    loads = g.step()
    assert loads.shape == (4, 16)
    assert np.allclose(loads.sum(axis=1), 1.0, atol=1e-6)
    assert (loads >= 0).all()
    dem = g.device_demand(loads[0], MIXTRAL_8X7B, 4)
    assert (np.diag(dem) == 0).all()
    assert (dem >= 0).all()


def test_cost_table_prices_loaded():
    for gbps in (100, 200, 400, 800):
        c = costm.fabric_cost("mixnet", 128, gbps)
        f = costm.fabric_cost("fat-tree", 128, gbps)
        assert 0 < c < f  # Fig 11: MixNet always cheaper than fat-tree
