"""Expert-placement solver (TPU-native Algorithm 1 analogue) + failure
handler properties."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.controlplane import ControlPlane, FailureHandler
from repro.core.placement import (
    apply_placement,
    inverse_permutation,
    placement_cost,
    solve_expert_placement,
)


@given(seed=st.integers(0, 200), epd=st.sampled_from([1, 2, 4]))
@settings(max_examples=30, deadline=None)
def test_placement_never_worse(seed, epd):
    rng = np.random.default_rng(seed)
    n_exp = 8 * epd
    demand = rng.random((8, n_exp)) * (rng.random((8, n_exp)) < 0.3)
    plan = solve_expert_placement(demand, epd)
    assert sorted(plan.perm.tolist()) == list(range(n_exp))  # a permutation
    assert plan.cost_after <= plan.cost_before + 1e-9
    assert plan.cost_after == pytest.approx(
        placement_cost(demand, plan.perm, epd)
    )


def test_placement_finds_obvious_colocation():
    """Device 0's tokens all go to expert 7 (hosted on device 7 under the
    identity) — the solver should relieve that bottleneck."""
    n_dev, n_exp = 8, 8
    demand = np.zeros((n_dev, n_exp))
    demand[0, 7] = 100.0
    demand[0, 0] = 1.0  # tiny local load
    plan = solve_expert_placement(demand, 1)
    assert plan.cost_after < plan.cost_before
    # expert 7 should now live on device 0 (traffic becomes local).
    assert plan.perm[7] // 1 == 0


def test_apply_placement_roundtrip():
    import jax.numpy as jnp

    w = {"w_in": jnp.arange(4 * 3 * 2).reshape(4, 3, 2)}
    perm = np.array([2, 0, 3, 1])
    moved = apply_placement(w, perm)
    # slot s holds the expert e with perm[e] == s
    inv = inverse_permutation(perm)
    for s in range(4):
        assert (np.asarray(moved["w_in"][s]) == np.asarray(w["w_in"][inv[s]])).all()


def test_controlplane_hysteresis():
    cp = ControlPlane(4, 8, num_devices=8, min_gain_fraction=0.5)
    uniform = np.ones((8, 8)) / 8
    plan = cp.plan(0, uniform)
    assert not plan.reconfigure  # no gain on uniform demand


def test_failure_handler_remap():
    fh = FailureHandler(num_experts=8, num_devices=4)
    fh.fail_device(2)
    slots = fh.remap()
    # every expert has a slot on a healthy device
    for e, s in enumerate(slots):
        assert fh.device_of_slot(int(s)) != 2
    # healthy experts untouched (minimal movement)
    for e in range(8):
        if e // 2 != 2:
            assert slots[e] == e
    fh.restore_device(2)
    assert fh.healthy_devices() == [0, 1, 2, 3]


def test_failure_handler_all_dead():
    fh = FailureHandler(8, 4)
    fh.fail_device(0), fh.fail_device(1), fh.fail_device(2)
    with pytest.raises(RuntimeError):
        fh.fail_device(3)
