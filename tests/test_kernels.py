"""Per-kernel allclose sweeps: Pallas (interpret=True) vs pure-jnp oracle,
across shapes and dtypes."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import (
    flash_attention_pallas,
    paged_flash_decode_pallas,
)
from repro.kernels.grouped_matmul import grouped_matmul_pallas, pick_block
from repro.kernels.topk_gating import topk_gating_pallas

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize(
    "e,c,d,f",
    [(1, 8, 16, 16), (4, 64, 128, 256), (2, 32, 96, 64), (8, 128, 512, 384), (3, 16, 48, 80)],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul(e, c, d, f, dtype):
    k1, k2 = jax.random.split(KEY)
    x = jax.random.normal(k1, (e, c, d), dtype)
    w = jax.random.normal(k2, (e, d, f), dtype)
    out = grouped_matmul_pallas(x, w, interpret=True)
    expect = ref.grouped_matmul(x, w)
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    assert out.shape == (e, c, f) and out.dtype == dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - expect.astype(jnp.float32)))) < tol * max(d, 1)


@pytest.mark.parametrize("t,e,k", [(64, 8, 2), (256, 64, 6), (128, 160, 6), (96, 16, 4), (32, 4, 1)])
def test_topk_gating(t, e, k):
    logits = jax.random.normal(KEY, (t, e), jnp.float32) * 2.0
    w, i = topk_gating_pallas(logits, k, interpret=True)
    rw, ri = ref.topk_gating(logits, k)
    assert float(jnp.max(jnp.abs(w - rw))) < 1e-5
    assert bool(jnp.all(i == ri))
    # weights sorted descending, valid expert range
    assert bool(jnp.all(w[:, :-1] >= w[:, 1:] - 1e-6))
    assert bool(jnp.all((i >= 0) & (i < e)))


@pytest.mark.parametrize(
    "case",
    [
        dict(b=2, hq=4, hkv=2, s=128, d=32, causal=True, window=None, softcap=None),
        dict(b=1, hq=8, hkv=8, s=256, d=64, causal=True, window=64, softcap=None),
        dict(b=1, hq=4, hkv=1, s=128, d=64, causal=True, window=None, softcap=50.0),
        dict(b=1, hq=2, hkv=2, s=192, d=64, causal=False, window=None, softcap=None),
        dict(b=2, hq=6, hkv=2, s=64, d=16, causal=True, window=16, softcap=20.0),
    ],
)
def test_flash_attention(case):
    c = dict(case)
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (c["b"], c["hq"], c["s"], c["d"]), jnp.float32)
    k = jax.random.normal(kk, (c["b"], c["hkv"], c["s"], c["d"]), jnp.float32)
    v = jax.random.normal(kv, (c["b"], c["hkv"], c["s"], c["d"]), jnp.float32)
    kw = dict(causal=c["causal"], window=c["window"], softcap=c["softcap"])
    out = flash_attention_pallas(q, k, v, bq=64, bk=64, interpret=True, **kw)
    expect = ref.flash_attention(q, k, v, **kw)
    assert float(jnp.max(jnp.abs(out - expect))) < 2e-5


@pytest.mark.parametrize(
    "case",
    [
        # C=1 decode and C>1 chunked continuations, GQA and MQA, page sizes
        # that do and don't divide the context, window and softcap on/off.
        dict(b=2, c=1, hq=4, hkv=2, d=32, page=16, p=4, window=None, softcap=None),
        dict(b=1, c=4, hq=4, hkv=1, d=64, page=8, p=6, window=None, softcap=30.0),
        dict(b=3, c=1, hq=2, hkv=2, d=16, page=16, p=2, window=16, softcap=None),
        dict(b=2, c=5, hq=6, hkv=3, d=32, page=8, p=8, window=24, softcap=25.0),
    ],
)
def test_paged_flash_decode_bit_exact_vs_oracle(case):
    """The acceptance gate for the paged decode kernel: interpret-mode pallas
    output is BIT-identical to the kernels/ref.py oracle (same streaming
    schedule), and allclose to a dense masked softmax over the gathered view."""
    c = dict(case)
    b, ch, hq, hkv, d, page, p = (
        c["b"], c["c"], c["hq"], c["hkv"], c["d"], c["page"], c["p"]
    )
    n_pool = b * p + 3
    kq, kk, kv, kt = jax.random.split(KEY, 4)
    q = jax.random.normal(kq, (b, ch, hq, d), jnp.float32)
    k_pool = jax.random.normal(kk, (n_pool, page, hkv, d), jnp.float32)
    v_pool = jax.random.normal(kv, (n_pool, page, hkv, d), jnp.float32)
    # Non-contiguous page ids; every sequence owns p distinct pool pages but
    # entries past its used span are -1 (unallocated).
    perm = jax.random.permutation(kt, n_pool)[: b * p].reshape(b, p)
    lengths = jnp.asarray(
        [(p * page - ch) - (i * page) // 2 for i in range(b)], jnp.int32
    )
    used = -(-(lengths + ch) // page)  # pages actually mapped
    table = jnp.where(jnp.arange(p)[None, :] < used[:, None], perm, -1)
    kw = dict(window=c["window"], softcap=c["softcap"])

    out = paged_flash_decode_pallas(
        q, k_pool, v_pool, table, lengths, interpret=True, **kw
    )
    oracle = ref.paged_flash_decode(q, k_pool, v_pool, table, lengths, **kw)
    assert out.shape == (b, ch, hq, d)
    assert float(jnp.max(jnp.abs(out - oracle))) == 0.0, c

    # dense reference: full softmax over the contiguous gathered view
    ck = ref.paged_gather_kv(k_pool, table)  # [B, P*page, Hkv, D]
    cv = ref.paged_gather_kv(v_pool, table)
    qg = q.reshape(b, ch, hkv, hq // hkv, d)
    s = jnp.einsum("bckgd,bskd->bkgcs", qg * (d**-0.5), ck)
    if c["softcap"]:
        s = jnp.tanh(s / c["softcap"]) * c["softcap"]
    q_pos = lengths[:, None, None, None, None] + jnp.arange(ch)[None, None, None, :, None]
    k_pos = jnp.arange(p * page)[None, None, None, None, :]
    mask = k_pos <= q_pos
    if c["window"]:
        mask &= k_pos > q_pos - c["window"]
    s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    expect = jnp.einsum("bkgcs,bskd->bckgd", w, cv).reshape(b, ch, hq, d)
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-5, c


@pytest.mark.parametrize("c", [1, 2, 4])
def test_paged_flash_decode_verify_spans(c):
    """Speculative verify reads the pool at span widths C in {1, 2, 4}
    (serial decode, K=1 and K=3 draft/verify): interpret-mode pallas must be
    BIT-identical to the kernels/ref.py oracle on a permuted, non-contiguous
    page table — the verify pass re-scores drafted positions in place, so
    even ULP-level drift would break bit-exact acceptance."""
    b, hq, hkv, d, page, p = 3, 4, 2, 32, 8, 6
    n_pool = b * p + 5
    kq, kk, kv, kt = jax.random.split(jax.random.PRNGKey(c), 4)
    q = jax.random.normal(kq, (b, c, hq, d), jnp.float32)
    k_pool = jax.random.normal(kk, (n_pool, page, hkv, d), jnp.float32)
    v_pool = jax.random.normal(kv, (n_pool, page, hkv, d), jnp.float32)
    perm = jax.random.permutation(kt, n_pool)[: b * p].reshape(b, p)
    lengths = jnp.asarray([p * page - c - 1 - 3 * i for i in range(b)],
                          jnp.int32)
    used = -(-(lengths + c) // page)
    table = jnp.where(jnp.arange(p)[None, :] < used[:, None], perm, -1)
    out = paged_flash_decode_pallas(
        q, k_pool, v_pool, table, lengths, interpret=True
    )
    oracle = ref.paged_flash_decode(q, k_pool, v_pool, table, lengths)
    assert out.shape == (b, c, hq, d)
    assert float(jnp.max(jnp.abs(out - oracle))) == 0.0, c


def test_flash_attention_chunked_matches_ref():
    kq, kk, kv = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (2, 4, 256, 32))
    k = jax.random.normal(kk, (2, 2, 256, 32))
    v = jax.random.normal(kv, (2, 2, 256, 32))
    for kw in [dict(causal=True), dict(causal=True, window=64),
               dict(causal=True, softcap=30.0), dict(causal=False)]:
        a = ref.flash_attention(q, k, v, **kw)
        b = ref.flash_attention_chunked(q, k, v, bq=64, **kw)
        assert float(jnp.max(jnp.abs(a - b))) < 3e-6, kw


@pytest.mark.parametrize("n,b,d,f,e", [(4, 8, 32, 48, 3), (6, 16, 64, 128, 4), (2, 8, 16, 16, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_grouped_matmul_blocks(n, b, d, f, e, dtype):
    """Block-wise (dropless MegaBlocks layout) grouped GEMM: Pallas
    scalar-prefetch kernel vs the scan oracle vs a direct gather matmul."""
    from repro.kernels.grouped_matmul import grouped_matmul_blocks_pallas

    k1, k2, k3 = jax.random.split(KEY, 3)
    x = jax.random.normal(k1, (n, b, d), dtype)
    w = jax.random.normal(k2, (e, d, f), dtype)
    be = jax.random.randint(k3, (n,), 0, e)
    out = grouped_matmul_blocks_pallas(x, w, be, interpret=True)
    expect = ref.grouped_matmul_blocks(x, w, be)
    direct = jnp.einsum(
        "nbd,ndf->nbf", x.astype(jnp.float32), w.astype(jnp.float32)[be]
    )
    tol = 1e-4 if dtype == jnp.float32 else 3e-2
    assert out.shape == (n, b, f) and out.dtype == dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - expect.astype(jnp.float32)))) < tol * d
    assert float(jnp.max(jnp.abs(expect.astype(jnp.float32) - direct))) < tol * d


@pytest.mark.parametrize("t,d,p", [(16, 32, 24), (64, 128, 64), (8, 48, 40)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_moe_dispatch_kernel(t, d, p, dtype):
    """Scalar-prefetch gather kernel vs the jnp oracle, with empty slots."""
    from repro.kernels.moe_dispatch import moe_dispatch_pallas

    x = jax.random.normal(KEY, (t, d), dtype)
    src = jax.random.randint(jax.random.PRNGKey(1), (p,), -1, t)
    out = moe_dispatch_pallas(x, src, interpret=True)
    expect = ref.moe_dispatch(x, src)
    assert out.shape == (p, d) and out.dtype == dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - expect.astype(jnp.float32)))) == 0.0
    # empty slots are zeroed
    assert float(jnp.max(jnp.abs(out[src < 0].astype(jnp.float32)))) == 0.0


@pytest.mark.parametrize("t,s,d,p", [(16, 2, 32, 40), (32, 4, 64, 96), (8, 1, 16, 8)])
def test_moe_combine_kernel(t, s, d, p):
    """Weighted combine kernel vs the jnp oracle, with dropped choices."""
    from repro.kernels.moe_dispatch import moe_combine_pallas

    y = jax.random.normal(KEY, (p, d))
    slot = jax.random.randint(jax.random.PRNGKey(1), (t, s), -1, p)
    w = jax.random.uniform(jax.random.PRNGKey(2), (t, s))
    out = moe_combine_pallas(y, slot, w, interpret=True)
    expect = ref.moe_combine(y, slot, w)
    assert out.shape == (t, d) and out.dtype == jnp.float32
    assert float(jnp.max(jnp.abs(out - expect))) < 1e-5
    # a token whose every choice is dropped combines to exactly zero
    all_dropped = jnp.all(slot < 0, axis=1)
    assert float(jnp.max(jnp.abs(jnp.where(all_dropped[:, None], out, 0.0)))) == 0.0


def test_dispatch_combine_roundtrip():
    """dispatch -> combine with unit weights reconstructs kept token rows."""
    from repro.models import routing

    t, d, buckets, k = 24, 16, 4, 2
    x = jax.random.normal(KEY, (t, d))
    dest = jax.random.randint(jax.random.PRNGKey(3), (t * k,), 0, buckets)
    rank, counts = routing.bucket_ranks(dest, buckets)
    plan = routing.dropless_plan(dest, rank, counts, None, buckets, 8)
    src_tok = jnp.where(plan.src >= 0, plan.src // k, -1)
    packed = ref.moe_dispatch(x, src_tok)
    back = ref.moe_combine(
        packed, plan.slot.reshape(t, k), jnp.ones((t, k)) / k
    )
    assert float(jnp.max(jnp.abs(back - x))) < 1e-6


def test_pick_block():
    assert pick_block(256, 128) == 128
    assert pick_block(96, 128) == 96
    assert pick_block(100, 64) == 50
    assert pick_block(7, 4) == 1


@pytest.mark.parametrize("t,d", [(64, 128), (256, 512), (96, 48)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_kernel(t, d, dtype):
    from repro.kernels.rmsnorm import rmsnorm_pallas
    from repro.models.layers import rms_norm

    x = jax.random.normal(KEY, (t, d), dtype) * 3
    w = jax.random.normal(KEY, (d,), dtype) * 0.1
    out = rmsnorm_pallas(x, w, interpret=True)
    expect = rms_norm(x[None], w)[0]
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    assert out.dtype == dtype
    assert float(jnp.max(jnp.abs(out.astype(jnp.float32) - expect.astype(jnp.float32)))) < tol


@pytest.mark.parametrize("l,p,n", [(16, 16, 8), (64, 32, 16), (32, 64, 32)])
def test_ssd_chunk_kernel(l, p, n):
    """Pallas SSD chunk vs a direct O(L^2) reference."""
    from repro.kernels.ssd_chunk import ssd_chunk_pallas
    import numpy as np

    g = 3
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    x = jax.random.normal(k1, (g, l, p)) * 0.5
    da = -jnp.abs(jax.random.normal(k2, (g, l))) * 0.1
    bm = jax.random.normal(k3, (g, l, n)) * 0.5
    cm = jax.random.normal(k4, (g, l, n)) * 0.5
    y, st = ssd_chunk_pallas(x, da, bm, cm, interpret=True)

    # reference
    cum = jnp.cumsum(da, axis=1)
    cb = jnp.einsum("gln,gsn->gls", cm, bm)
    gate = jnp.exp(cum[:, :, None] - cum[:, None, :])
    mask = np.tril(np.ones((l, l), bool))
    y_ref = jnp.einsum("gls,gls,gsp->glp", cb, jnp.where(mask, gate, 0.0), x)
    st_ref = jnp.einsum("gsn,gs,gsp->gnp", bm, jnp.exp(cum[:, -1:] - cum), x)
    assert float(jnp.max(jnp.abs(y - y_ref))) < 1e-4
    assert float(jnp.max(jnp.abs(st - st_ref))) < 1e-4
