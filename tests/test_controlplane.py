"""Unified control-plane engine: per-layer decisions, permutation
composition across repeated reconfigurations, and §5.4 failure handling
driven through the same decide/apply path in both placement (trainer) and
OCS (simulator) modes."""

import numpy as np
import pytest

from repro.core.controlplane import ControlPlane, FailureHandler
from repro.core.fabric import FabricConfig, make_fabric
from repro.core.placement import inverse_permutation
from repro.train.trainer import permute_expert_weights

import jax.numpy as jnp


def make_engine(layers=2, experts=8, devices=4, **kw):
    kw.setdefault("use_copilot", False)
    kw.setdefault("min_gain_fraction", 0.01)
    return ControlPlane(layers, experts, num_devices=devices, **kw)


def fake_block_params(layers, ev, d=3, f=2):
    """Stacked expert weights whose values encode (layer, expert) identity."""
    w = np.arange(layers * ev, dtype=np.float64).reshape(layers, ev, 1, 1)
    w = np.broadcast_to(w, (layers, ev, d, f)).copy()
    return {
        "blocks": {
            "0_global": {
                "moe": {
                    "w_in": jnp.asarray(w),
                    "w_gate": jnp.asarray(w + 0.5),
                    "w_out": jnp.asarray(np.swapaxes(w, 2, 3) + 0.25),
                },
                "norm1": jnp.zeros((layers, d)),  # non-expert leaf, untouched
            }
        }
    }


def apply_like_trainer(cp, params, plans):
    """Mirror Trainer._apply_layer_plans: weights first, then engine perms."""
    live = [p for p in plans if p.reconfigure]
    inv_stack = np.tile(np.arange(cp.num_virtual), (cp.num_layers, 1))
    for p in live:
        inv_stack[p.layer] = inverse_permutation(p.perm)
    params = permute_expert_weights(params, inv_stack, cp.num_virtual)
    for p in live:
        cp.apply(p)
    return params


def hot_demand(devices, experts, hot_expert, hot=60.0, seed=0):
    """Device 0 sends a hot flow to one expert: co-locating that expert on
    device 0 relieves the bottleneck (the example-6 scenario)."""
    rng = np.random.default_rng(seed)
    d = rng.random((devices, experts)) * (rng.random((devices, experts)) < 0.3)
    d[0, hot_expert] += hot
    return d


# -- per-layer decisions -----------------------------------------------------


def test_two_layers_with_different_loads_get_different_perms():
    """The acceptance-criterion scenario: per-layer loads -> per-layer perms
    (the regional per-layer reconfiguration the old trainer averaged away)."""
    cp = make_engine(layers=2, experts=8, devices=4)
    # Two hot experts sharing a device: splitting them across devices halves
    # the hosting device's ingress — but the hot pair differs per layer.
    load0 = np.array([30.0, 30, 1, 1, 1, 1, 1, 1])
    load1 = np.array([1.0, 1, 1, 1, 1, 1, 30, 30.0])
    cp.observe(0, load0)
    cp.observe(1, load1)
    cp.end_step()
    plans = [cp.plan(0), cp.plan(1)]
    assert plans[0].reconfigure and plans[1].reconfigure
    for p in plans:
        cp.apply(p)
    stack = cp.perm_stack()
    assert stack.shape == (2, 8)
    assert (stack[0] != stack[1]).any(), stack
    for row in stack:
        assert sorted(row.tolist()) == list(range(8))


def test_plan_without_observation_declines():
    cp = make_engine()
    plan = cp.plan(0)
    assert not plan.reconfigure
    assert plan.reason == "no traffic observed"


# -- repeated reconfiguration composition (trainer regression) ---------------


def test_repeated_reconfig_composition_router_matches_weights():
    """After >= 2 consecutive reconfigurations, each layer's expert weights
    must sit in exactly the slots the router's perm_stack addresses
    (regression for the ``perm[base]`` composition ordering)."""
    layers, experts, devices = 2, 8, 4
    cp = make_engine(layers=layers, experts=experts, devices=devices)
    params = fake_block_params(layers, experts)
    original = np.asarray(params["blocks"]["0_global"]["moe"]["w_in"]).copy()

    for round_, hot in enumerate(((0, 7), (5, 2), (3, 6))):
        plans = [
            cp.plan(l, hot_demand(devices, experts, hot[l], seed=round_))
            for l in range(layers)
        ]
        assert all(p.reconfigure for p in plans), [p.reason for p in plans]
        params = apply_like_trainer(cp, params, plans)

    assert cp.reconfig_count >= 2 * layers
    stack = cp.perm_stack()
    w_in = np.asarray(params["blocks"]["0_global"]["moe"]["w_in"])
    for l in range(layers):
        assert (stack[l] != np.arange(experts)).any()  # actually moved
        for e in range(experts):
            # the slot the router sends expert e's tokens to holds e's weights
            np.testing.assert_array_equal(w_in[l, stack[l][e]], original[l, e])
    # non-expert leaves untouched
    assert np.asarray(params["blocks"]["0_global"]["norm1"]).sum() == 0.0


def test_permute_expert_weights_identity_rows_noop():
    layers, experts = 3, 4
    params = fake_block_params(layers, experts)
    before = np.asarray(params["blocks"]["0_global"]["moe"]["w_out"]).copy()
    inv_stack = np.tile(np.arange(experts), (layers, 1))
    inv_stack[1] = np.array([1, 0, 3, 2])
    params = permute_expert_weights(params, inv_stack, experts)
    after = np.asarray(params["blocks"]["0_global"]["moe"]["w_out"])
    np.testing.assert_array_equal(after[0], before[0])
    np.testing.assert_array_equal(after[2], before[2])
    np.testing.assert_array_equal(after[1], before[1][[1, 0, 3, 2]])


# -- failure path (§5.4) through the engine ----------------------------------


def test_failover_plans_rehome_failed_device_placement_mode():
    layers, experts, devices = 2, 8, 4
    cp = make_engine(layers=layers, experts=experts, devices=devices)
    params = fake_block_params(layers, experts)
    original = np.asarray(params["blocks"]["0_global"]["moe"]["w_in"]).copy()
    epd = experts // devices

    plans = cp.fail_device(2)
    assert len(plans) == layers and all(p.reconfigure for p in plans)
    params = apply_like_trainer(cp, params, plans)
    stack = cp.perm_stack()
    w_in = np.asarray(params["blocks"]["0_global"]["moe"]["w_in"])
    for l in range(layers):
        for e in range(experts):
            if e // epd == 2:  # expert homed on the failed device
                assert stack[l][e] // epd != 2, (l, e, stack[l])
            # router/weight consistency survives the failover remap
            np.testing.assert_array_equal(w_in[l, stack[l][e]], original[l, e])

    # routine plans after the failure keep only cold experts parked there
    hot = hot_demand(devices, experts, hot_expert=1, seed=3)
    plan = cp.plan(0, hot)
    if plan.reconfigure:
        hot_slot = plan.perm[np.argmax(hot.sum(axis=0))]
        assert hot_slot // epd != 2
        cp.apply(plan)
    cp.restore_device(2)
    assert cp.failures.healthy_devices() == [0, 1, 2, 3]


def test_failover_remap_through_engine():
    """FailureHandler.remap driven through the engine's failover_slots."""
    cp = make_engine(layers=1, experts=8, devices=4)
    cp.fail_device(1)
    slots = cp.failover_slots()
    fh = cp.failures
    for e, s in enumerate(slots):
        assert fh.device_of_slot(int(s)) != 1
        if e // fh.experts_per_device != 1:
            assert s == e  # minimal movement for healthy experts


def test_failure_handler_swap_remap_is_bounded_permutation():
    fh = FailureHandler(num_experts=8, num_devices=4)
    fh.fail_device(0)
    fh.fail_device(3)
    perm = fh.swap_remap()
    assert sorted(perm.tolist()) == list(range(8))
    for e in range(8):
        if e // 2 in (0, 3):
            assert perm[e] // 2 not in (0, 3), (e, perm)


def test_failure_handler_all_dead():
    fh = FailureHandler(8, 4)
    fh.fail_device(0), fh.fail_device(1), fh.fail_device(2)
    with pytest.raises(RuntimeError):
        fh.fail_device(3)


def test_simulation_failures_through_engine_degraded_but_finite():
    """NIC + full-OCS failures injected via the engine: the simulated run
    continues, costs stay finite, and degradation stays bounded."""
    from repro.configs.paper_models import MIXTRAL_8X7B
    from repro.core.netsim import simulate_training

    cfg = FabricConfig(num_servers=128, link_gbps=400)
    fab_h = make_fabric("mixnet", cfg)
    healthy = simulate_training(MIXTRAL_8X7B, fab_h, iterations=3)
    t_healthy = float(np.mean([r.total for r in healthy[1:]]))

    fab = make_fabric("mixnet", cfg)
    cp = ControlPlane.for_simulation(MIXTRAL_8X7B, fab)
    cp.fail_nic(0, failed_nics=2)
    cp.fail_device(1)
    failed = simulate_training(
        MIXTRAL_8X7B, fab, iterations=3, seed=1, controlplane=cp
    )
    t_failed = float(np.mean([r.total for r in failed[1:]]))
    assert np.isfinite(t_failed) and t_failed > 0
    assert all(np.isfinite(r.total) for r in failed)
    assert t_failed < t_healthy * 1.5  # degraded, not collapsed (Fig 14)
    assert t_failed > t_healthy * 0.9


def test_ocs_mode_plan_requires_demand():
    fab = make_fabric("mixnet", FabricConfig(num_servers=8))
    cp = ControlPlane(2, 8, num_devices=4, fabric=fab, use_copilot=False)
    with pytest.raises(ValueError):
        cp.plan(0)


def test_ocs_mode_hide_or_block_accounting():
    """apply() charges only the un-hidden part of the reconfig delay."""
    fab = make_fabric("mixnet", FabricConfig(num_servers=8, reconfig_delay_s=0.025))
    cp = ControlPlane(2, 8, num_devices=8, fabric=fab, use_copilot=False)
    demand = np.random.default_rng(0).random((8, 8)) * 1e9
    # fully hidden: infinite window
    assert cp.apply(cp.plan(0, demand)) == 0.0
    # partially hidden: 10 ms window hides 10 of the 25 ms
    blocked = cp.apply(cp.plan(0, demand), hide_window=0.010)
    assert blocked == pytest.approx(0.015)
    # no window: full delay blocks
    blocked = cp.apply(cp.plan(0, demand), hide_window=0.0)
    assert blocked == pytest.approx(0.025)
    assert cp.reconfig_count == 3
