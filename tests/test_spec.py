"""Speculative decoding over the paged KV pool (DESIGN.md §11): draft/verify
tick parity vs serial decode (greedy AND sampled, dropless/capacity, reconfig
on/off, single- and multi-device), EOS landing at every position of a span,
draft-truncation page reclaim with a no-leak check after every tick, and the
netsim acceptance-vs-goodput/$ pricing."""

import dataclasses

import jax
import numpy as np
import pytest

from repro.models import moe as moe_mod
from repro.models import routing
from repro.models.config import ModelConfig, MoEConfig
from repro.models.transformer import init_model
from repro.parallel.sharding import make_plan
from repro.serve.batching import Request
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.paged import PageAllocator

PLAN = make_plan(None)


def _dense_toy():
    cfg = ModelConfig("sp", "dense", 2, 32, 4, 2, 64, 64, dtype="float32",
                      remat="none")
    params, _ = init_model(jax.random.PRNGKey(0), cfg, PLAN)
    return cfg, params


def _moe_toy(dispatch="dropless", shared=1):
    cfg = ModelConfig(
        "sps", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32,
                      num_shared_experts=shared, capacity_factor=8.0,
                      backend="mixnet", a2a_group=2, dispatch=dispatch),
    )
    params, _ = init_model(jax.random.PRNGKey(1), cfg, PLAN)
    return cfg, params


def _prompts(vocab, seed=3, sizes=(5, 9, 12, 7)):
    rng = np.random.default_rng(seed)
    return [rng.integers(1, vocab - 1, size=int(n)).astype(np.int32)
            for n in sizes]


def _serve(params, cfg, prompts, *, spec_k, sample=False, sample_seed=0,
           eos=None, max_new=8, reconfig=False, page_size=8, max_len=48,
           leak_check=True):
    scfg = ServeConfig(
        slots=2, max_len=max_len, prefill_chunk=0, paged=True,
        page_size=page_size, spec_k=spec_k, sample=sample,
        sample_seed=sample_seed,
        reconfig_every=(3 if reconfig else 0),
        reconfig_min_gain=0.0, num_devices=4,
    )
    eng = ServeEngine(jax.tree.map(lambda a: a, params), cfg, PLAN, scfg)
    for i, p in enumerate(prompts):
        eng.submit(Request(rid=100 + i, prompt=np.asarray(p, np.int32),
                           max_new_tokens=max_new, eos_id=eos))
    while eng.batcher.busy:
        eng.step()
        if leak_check:
            # satellite: the page pool must balance after EVERY tick —
            # truncation returns pages immediately, never strands them.
            eng.batcher.alloc.check_leaks()
    rep = eng.report(1.0)
    outs = {r.rid: list(r.out) for r in eng.batcher.finished}
    assert len(outs) == len(prompts)
    return outs, rep, eng


# ---------------------------------------------------------------------------
# draft-mode plumbing (config/routing level)
# ---------------------------------------------------------------------------


def test_effective_top_k_and_resolve():
    assert routing.effective_top_k(2, "off") == 2
    assert routing.effective_top_k(2, "topk1") == 1
    assert routing.effective_top_k(1, "topk1") == 1
    assert routing.effective_top_k(2, "shared_only") == 0
    dense_cfg, _ = _dense_toy()
    assert moe_mod.resolve_draft_mode(dense_cfg, "auto") == "off"
    shared_cfg, _ = _moe_toy(shared=1)
    assert moe_mod.resolve_draft_mode(shared_cfg, "auto") == "shared_only"
    plain_cfg = ModelConfig(
        "spt", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(8, 2, 32, capacity_factor=8.0, backend="mixnet",
                      a2a_group=2),
    )
    assert moe_mod.resolve_draft_mode(plain_cfg, "auto") == "topk1"
    dc = moe_mod.draft_config(shared_cfg, "auto")
    assert dc.moe.draft_mode == "shared_only"
    assert shared_cfg.moe.draft_mode == "off"  # original untouched
    with pytest.raises(ValueError):
        routing.compute_routing(
            jax.numpy.zeros((4, 8)), top_k=2, num_virtual=8, replication=1,
            draft_mode="shared_only")


# ---------------------------------------------------------------------------
# allocator: truncation returns pages immediately (satellite)
# ---------------------------------------------------------------------------


def test_allocator_truncate_frees_pages_and_restores_reservation():
    al = PageAllocator(slots=2, page_size=4, max_pages=6, num_pages=12,
                       prefix_cache=False)
    assert al.admit(0, np.arange(6), 8, 24) is not None
    al.ensure(0, 0, 14)  # 4 pages mapped (ceil(14/4))
    free_before = len(al._free)
    reserved_before = al._reserved[0]
    freed = al.truncate(0, 7)  # back to 2 pages
    assert freed == 2 and al.pages_reclaimed == 2 and al.draft_truncations == 1
    assert len(al._free) == free_before + 2
    assert al._reserved[0] == reserved_before + 2  # reservation restored
    assert (al.table[0, 2:] == -1).all() and (al.table[0, :2] >= 0).all()
    al.check_leaks()
    # the freed headroom is immediately re-mappable
    al.ensure(0, 7, 14)
    assert (al.table[0, :4] >= 0).all()
    al.check_leaks()
    # truncation inside the same page frees nothing but still counts
    assert al.truncate(0, 13) == 0
    assert al.draft_truncations == 2
    al.check_leaks()


# ---------------------------------------------------------------------------
# engine parity: spec vs serial, single device
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("sample", [False, True])
def test_spec_parity_dense(sample):
    """Dense toy (draft == full model): spec emits the exact serial stream,
    greedy and sampled, with the pool balancing after every tick."""
    cfg, params = _dense_toy()
    prompts = _prompts(cfg.vocab_size)
    base, _, _ = _serve(params, cfg, prompts, spec_k=0, sample=sample)
    spec, rep, _ = _serve(params, cfg, prompts, spec_k=4, sample=sample)
    assert spec == base
    assert rep.spec_k == 4 and rep.spec_drafted > 0
    assert rep.spec_accepted > 0 and rep.spec_acceptance > 0.5


@pytest.mark.parametrize("dispatch", ["dropless", "capacity"])
@pytest.mark.parametrize("reconfig", [False, True])
def test_spec_parity_moe(dispatch, reconfig):
    """MoE (shared_only draft): bit-exact acceptance means the spec engine's
    output is token-for-token the serial stream even when the draft is wrong
    most of the time, across dispatch modes and under decode-time
    reconfiguration."""
    cfg, params = _moe_toy(dispatch)
    prompts = _prompts(cfg.vocab_size, seed=9)
    base, rep_b, _ = _serve(params, cfg, prompts, spec_k=0, reconfig=reconfig)
    spec, rep_s, _ = _serve(params, cfg, prompts, spec_k=3, reconfig=reconfig)
    assert spec == base, (dispatch, reconfig)
    assert rep_s.spec_drafted > 0
    if reconfig:
        assert rep_s.reconfig_count > 0


def test_spec_parity_moe_topk1_sampled():
    """No shared expert: the draft narrows to top-1 routing; sampled decode
    still reproduces the serial stream via the per-(row, position) keys."""
    cfg, params = _moe_toy("capacity", shared=0)
    assert moe_mod.resolve_draft_mode(cfg, "auto") == "topk1"
    prompts = _prompts(cfg.vocab_size, seed=13)
    base, _, _ = _serve(params, cfg, prompts, spec_k=3, sample=True,
                        sample_seed=7)
    spec, _, _ = _serve(params, cfg, prompts, spec_k=0, sample=True,
                        sample_seed=7)
    assert spec == base


def test_spec_sampled_seed_discipline():
    """Same seed -> identical sampled streams (spec and serial); a different
    seed draws a different stream (the keys really are threaded)."""
    cfg, params = _dense_toy()
    prompts = _prompts(cfg.vocab_size, seed=21, sizes=(6, 11))
    a, _, _ = _serve(params, cfg, prompts, spec_k=4, sample=True,
                     sample_seed=5)
    b, _, _ = _serve(params, cfg, prompts, spec_k=0, sample=True,
                     sample_seed=5)
    c, _, _ = _serve(params, cfg, prompts, spec_k=4, sample=True,
                     sample_seed=6)
    assert a == b
    assert a != c


def test_spec_truncation_reclaims_pages():
    """A draft the verifier mostly rejects: truncation fires, crosses page
    boundaries (page_size=4 < K+1), and the reclaimed pages are visible in
    the report — with the pool balancing after every tick."""
    cfg, params = _moe_toy("dropless")
    prompts = _prompts(cfg.vocab_size, seed=17)
    base, _, _ = _serve(params, cfg, prompts, spec_k=0, page_size=4)
    spec, rep, _ = _serve(params, cfg, prompts, spec_k=4, page_size=4)
    assert spec == base
    assert rep.draft_truncations > 0, "random-weight draft never rejected?"
    assert rep.pages_reclaimed > 0, "rejection never crossed a page boundary"


# ---------------------------------------------------------------------------
# EOS inside a span (satellite)
# ---------------------------------------------------------------------------


def test_spec_eos_at_every_span_position():
    """Place EOS at every position 0..K of the FIRST K=4 span: the spec
    engine stops exactly where serial decode stops and discards the
    speculated tail beyond EOS."""
    cfg, params = _dense_toy()
    # need the first K+1 tokens distinct so eos==stream[j] stops AT j:
    # scan prompt seeds for a stream whose first span has no repeats
    for seed in range(29, 40):
        prompts = _prompts(cfg.vocab_size, seed=seed, sizes=(8,))
        ref, _, _ = _serve(params, cfg, prompts, spec_k=0, max_new=10)
        stream = ref[100]
        if len(set(stream[:5])) == 5:
            break
    else:
        pytest.fail(f"no seed gave 5 distinct first-span tokens: {stream[:5]}")
    for j in range(5):
        eos = stream[j]
        b, _, _ = _serve(params, cfg, prompts, spec_k=0, max_new=10, eos=eos)
        s, rep, _ = _serve(params, cfg, prompts, spec_k=4, max_new=10, eos=eos)
        assert s == b, f"eos at span position {j}"
        assert s[100] == stream[: j + 1], f"eos at span position {j}"
        assert rep.completed == 1


# ---------------------------------------------------------------------------
# netsim pricing: acceptance curve must cross 1.0 (tentpole, priced side)
# ---------------------------------------------------------------------------


def test_netsim_spec_decode_pricing():
    from repro.configs.paper_models import MIXTRAL_8X7B
    from repro.core.fabric import FabricConfig, make_fabric
    from repro.core.netsim import simulate_serving

    model = dataclasses.replace(MIXTRAL_8X7B, num_blocks=8, overlap_chunks=4)
    fab = make_fabric("mixnet", FabricConfig(num_servers=128, link_gbps=400))
    mix = dataclasses.replace(
        __import__("repro.serve.workload", fromlist=["MIXES"]).MIXES[
            "agentic_shared"],
        rate_rps=500.0, arrival="poisson", num_regions=1)
    base = simulate_serving(model, fab, mix=mix, num_requests=24, slots=64,
                            use_reconfig=True, seed=1)
    lo = simulate_serving(model, fab, mix=mix, num_requests=24, slots=64,
                          use_reconfig=True, seed=1, spec_decode=(4, 0.05))
    hi = simulate_serving(model, fab, mix=mix, num_requests=24, slots=64,
                          use_reconfig=True, seed=1, spec_decode=(4, 0.95))
    assert base.spec_k == 0 and lo.spec_k == 4 and hi.spec_k == 4
    assert 0.0 < lo.spec_acceptance < hi.spec_acceptance <= 1.0
    assert hi.spec_tokens_per_round > lo.spec_tokens_per_round > 1.0
    # the draft pass is priced: junk drafts LOSE goodput/$, good drafts win
    assert lo.goodput_per_mdollar < base.goodput_per_mdollar
    assert hi.goodput_per_mdollar > base.goodput_per_mdollar
    # inter-token latency falls monotonically with acceptance
    assert hi.tpot_p50_s < base.tpot_p50_s
    # an acceptance MODEL (callable K -> expected accepted) is also accepted
    fn = simulate_serving(model, fab, mix=mix, num_requests=16, slots=64,
                          use_reconfig=True, seed=1,
                          spec_decode=(4, lambda k: 0.9 * k))
    assert fn.spec_tokens_per_round == pytest.approx(1.0 + 0.9 * 4)


# ---------------------------------------------------------------------------
# multi-device sweep: P x dispatch x reconfig, spec == serial
# ---------------------------------------------------------------------------


SPEC_SWEEP = """
import dataclasses
import jax, numpy as np
from repro.core.controlplane import LayerPlan
from repro.models.config import ModelConfig, MoEConfig
from repro.models.transformer import init_model
from repro.parallel.sharding import make_plan
from repro.serve.batching import Request
from repro.serve.engine import ServeConfig, ServeEngine
from repro.launch.mesh import make_mesh as _mm
from repro.launch.mesh import use_mesh as _um

P = %(P)d
mesh = _mm((P,), ("model",))
plan = make_plan(mesh)

for dispatch, shared in (("dropless", 1), ("capacity", 0)):
    cfg = ModelConfig(
        "sps", "moe", 2, 32, 4, 2, 0, 64, dtype="float32", remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=32,
                      num_shared_experts=shared, capacity_factor=8.0,
                      backend="mixnet", a2a_group=2, dispatch=dispatch),
    )
    params, _ = init_model(jax.random.PRNGKey(1), cfg, plan)
    rng = np.random.default_rng(9)
    prompts = [rng.integers(1, 63, size=int(n)).astype(np.int32)
               for n in (6, 11, 9)]

    def run(spec_k, reconfig):
        scfg = ServeConfig(slots=2, max_len=48, paged=True, page_size=8,
                           spec_k=spec_k,
                           reconfig_every=(3 if reconfig else 0),
                           reconfig_min_gain=0.0, num_devices=P)
        eng = ServeEngine(jax.tree.map(lambda a: a, params), cfg, plan, scfg,
                          mesh=mesh)
        with _um(mesh):
            if reconfig:
                perm = np.arange(8)
                perm[[0, 1]] = perm[[1, 0]]
                eng.apply_plans([
                    LayerPlan(l, True, perm=perm.copy())
                    for l in range(cfg.pattern_repeats)
                ])
            for i, p in enumerate(prompts):
                eng.submit(Request(rid=100 + i, prompt=p, max_new_tokens=5))
            while eng.batcher.busy:
                eng.step()
                eng.batcher.alloc.check_leaks()
        rep = eng.report(1.0)
        assert rep.completed == len(prompts)
        return {r.rid: list(r.out) for r in eng.batcher.finished}, rep

    for reconfig in (False, True):
        a, rep_s = run(3, reconfig)
        b, rep_b = run(0, reconfig)
        assert a == b, (dispatch, reconfig, a, b)
        assert rep_s.spec_drafted > 0
        if reconfig:
            assert rep_s.reconfig_count > 0
print("SPEC_SWEEP_OK_P%(P)d")
"""


@pytest.mark.parametrize("p", [2, 4, 8])
def test_spec_parity_multidevice(multidevice, p):
    """P-device EP-sharded serving: speculative decode is token-for-token
    the serial stream for shared_only AND topk1 drafts, dropless and
    capacity dispatch, reconfiguration on and off."""
    out = multidevice(SPEC_SWEEP % {"P": p}, devices=8, timeout=900)
    assert f"SPEC_SWEEP_OK_P{p}" in out
