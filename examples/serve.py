"""Reconfigurable expert-parallel serving (DESIGN.md §9).

Drives a ServeEngine over a named workload mix: continuous batching with
(optionally chunked) prefill, decode-time gate-load monitoring into the
MixNet control plane, and live expert re-placement between ticks — then
proves the generation-consistency guarantee by replaying the identical
workload with reconfiguration off and comparing tokens bit-for-bit.

    PYTHONPATH=src python examples/serve.py [--arch grok-1-314b]
        [--mix chat|batch_summarize|agentic] [--requests 8]
        [--prefill-chunk 8] [--no-parity-check]
"""

import argparse
import dataclasses
import sys

sys.path.insert(0, "src")

import jax
import numpy as np

from repro.configs import ARCH_NAMES, get_reduced
from repro.models.transformer import init_model
from repro.obs import metrics, trace
from repro.parallel.sharding import make_plan
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.workload import MIXES, WorkloadGenerator


def build_engine(params, cfg, plan, args, reconfig: bool) -> ServeEngine:
    scfg = ServeConfig(
        slots=args.slots,
        max_len=args.max_len,
        prefill_chunk=args.prefill_chunk,
        reconfig_every=args.reconfig_every if reconfig else 0,
        reconfig_min_gain=0.0,
        num_devices=args.num_devices,
    )
    return ServeEngine(jax.tree.map(lambda a: a, params), cfg, plan, scfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="grok-1-314b")
    ap.add_argument("--mix", choices=sorted(MIXES), default="chat")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=64)
    ap.add_argument("--prefill-chunk", type=int, default=8)
    ap.add_argument("--reconfig-every", type=int, default=4)
    ap.add_argument("--num-devices", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--no-parity-check", action="store_true")
    ap.add_argument("--trace", default="",
                    help="export a Perfetto trace of the run to this path")
    ap.add_argument("--metrics", default="",
                    help="dump the metrics-registry snapshot to this path")
    args = ap.parse_args()
    if args.trace:
        trace.enable()

    cfg = get_reduced(args.arch)
    if cfg.encoder_layers:
        # The text workload generator cannot produce encoder frames; the
        # encoder-decoder serving shapes run through examples/quickstart +
        # the dry-run cells instead (DESIGN.md §4).
        raise SystemExit(
            f"{args.arch} is encoder-decoder (audio) — ServeEngine serves "
            "pure-decoder archs; pick a text arch"
        )
    if not cfg.is_moe:
        print(f"{args.arch} is dense — serving runs without a control plane")
    if cfg.is_moe and cfg.moe.num_experts % args.num_devices:
        args.num_devices = 1
    # Chunked prefill needs attention-only block patterns (DESIGN.md §9).
    if any(k not in ("global", "local") for k in (*cfg.block_pattern, *cfg.tail_pattern)):
        args.prefill_chunk = 0
    if args.max_len < 8:
        raise SystemExit("--max-len must be >= 8")
    plan = make_plan(None)
    params, _ = init_model(jax.random.PRNGKey(0), cfg, plan)

    gen = WorkloadGenerator(args.mix, seed=args.seed, vocab_size=cfg.vocab_size)
    out_cap = max(1, min(12, args.max_len // 4))
    reqs = [
        dataclasses.replace(
            r,
            # leave decode room: prompt + outputs must fit the slot cache
            prompt_len=max(1, min(r.prompt_len, args.max_len - out_cap - 2)),
            max_new_tokens=min(r.max_new_tokens, out_cap),
        )
        for r in gen.generate(args.requests)
    ]

    print(f"serving reduced {args.arch} ({cfg.family}) on mix={args.mix}: "
          f"{len(reqs)} requests, {args.slots} slots, prefill_chunk={args.prefill_chunk}")
    eng = build_engine(params, cfg, plan, args, reconfig=cfg.is_moe)
    rep = eng.run(reqs, gen)
    print(f"  completed={rep.completed}/{rep.requests} in {rep.ticks} ticks "
          f"({rep.wall_s:.1f}s wall, {rep.tokens_per_s:.1f} tok/s incl. compile)")
    print(f"  TTFT p50/p99 = {rep.ttft_ticks_p50:.0f}/{rep.ttft_ticks_p99:.0f} ticks; "
          f"TPOT = {rep.tpot_ticks_mean:.2f} ticks/token")
    print(f"  reconfigurations applied: {rep.reconfig_count} "
          f"(wire: {rep.wire_reconfig_count}); decode a2a bytes accounted: "
          f"{rep.a2a_bytes:.0f}")
    if rep.gate_load_total is not None:
        share = rep.gate_load_total.sum(0) / max(rep.gate_load_total.sum(), 1e-9)
        print(f"  gate-load share per expert: {np.round(share, 2)}")
    if eng.decision_log:
        print("  decision log (control-plane verdict each cadence):")
        for d in eng.decision_log:
            if d["kind"] == "reconfig":
                verdict = (
                    f"moved layers {d['layers']} (gain {d['gain_bytes']:.0f} B)"
                    if d["applied"] else f"held placement ({'; '.join(d['reasons'])})"
                )
                print(f"    tick {d['tick']:>4}: {verdict}")
            else:
                print(f"    tick {d['tick']:>4}: {d['kind']} "
                      f"{({k: v for k, v in d.items() if k not in ('tick', 'kind')})}")

    if cfg.is_moe and not args.no_parity_check:
        base = build_engine(params, cfg, plan, args, reconfig=False)
        base.run(reqs, gen)
        a = {r.rid: r.out for r in eng.batcher.finished}
        b = {r.rid: r.out for r in base.batcher.finished}
        assert a == b, "reconfiguration changed generated tokens"
        print("  parity: tokens bit-identical with reconfiguration off ✓")

    if args.trace:
        n = trace.export(args.trace)
        failures = trace.validate_file(args.trace)
        assert not failures, f"trace schema failures: {failures[:3]}"
        print(f"  trace: {n} events -> {args.trace} (schema OK; open in "
              "ui.perfetto.dev)")
    if args.metrics:
        metrics.default().to_json(args.metrics)
        print(f"  metrics snapshot -> {args.metrics}")


if __name__ == "__main__":
    main()
