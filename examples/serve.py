"""Batched serving: prefill a prompt batch, then greedy-decode against the
flash-decoding KV caches — the ``serve_step`` the decode_32k / long_500k
dry-run cells lower, at toy scale.

    PYTHONPATH=src python examples/serve.py [--arch gemma2-2b]
"""

import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import ARCH_NAMES, get_reduced
from repro.models.transformer import init_model
from repro.parallel.sharding import make_plan
from repro.serve.decode import generate


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="gemma2-2b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--new-tokens", type=int, default=16)
    args = ap.parse_args()

    cfg = get_reduced(args.arch)
    plan = make_plan(None)
    params, _ = init_model(jax.random.PRNGKey(0), cfg, plan)
    prompt = jax.random.randint(
        jax.random.PRNGKey(1), (args.batch, args.prompt_len), 0, cfg.vocab_size
    )
    extra = {}
    if cfg.family == "audio":
        extra["frames"] = jax.random.normal(
            jax.random.PRNGKey(2), (args.batch, cfg.encoder_seq, cfg.d_model)
        )
    if cfg.vision_patches:
        extra["patches"] = jax.random.normal(
            jax.random.PRNGKey(3), (args.batch, cfg.vision_patches, cfg.d_model)
        )

    print(f"serving reduced {args.arch} ({cfg.family}): batch={args.batch} "
          f"prompt={args.prompt_len} new={args.new_tokens}")
    t0 = time.perf_counter()
    out = generate(params, cfg, plan, prompt,
                   max_new_tokens=args.new_tokens, extra_batch=extra)
    out.block_until_ready()
    dt = time.perf_counter() - t0
    print(f"generated {out.shape} tokens in {dt:.2f}s "
          f"({args.batch*args.new_tokens/dt:.1f} tok/s incl. compile)")
    print("first sequence:", out[0].tolist())


if __name__ == "__main__":
    main()
