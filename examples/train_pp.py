"""Pipeline-parallel MoE training over CommRuntime: a 2-stage x 4-way
expert-parallel mesh on 8 forced host devices, with a live mid-run
reconfiguration (expert->slot perm + wire re-address) flowing through the
stage pipe.

Every step's loss is parity-checked against the flat (non-PP) train step
running the same schedule — the PP composition is a scheduling change, not
a math change (DESIGN.md §13).

    python examples/train_pp.py [--steps 4]

(no PYTHONPATH needed; the script forces 8 host devices before jax loads.)
"""

import argparse
import os
import sys

sys.path.insert(0, "src")

# Must happen before jax is imported anywhere.
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=8")
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax
import jax.numpy as jnp
import numpy as np

from repro.launch.mesh import make_mesh, use_mesh
from repro.models.config import ModelConfig, MoEConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan, virtual_experts
from repro.train.pp_step import make_pp_train_step
from repro.train.train_step import init_all, make_train_step

STAGES, EP = 2, 4

CFG = ModelConfig(
    name="pp-demo-moe",
    family="moe",
    num_layers=4,
    d_model=32,
    num_heads=3,
    num_kv_heads=1,
    d_ff=0,
    vocab_size=64,
    head_dim=8,
    dtype="float32",
    remat="none",
    moe=MoEConfig(num_experts=4, top_k=2, d_ff=32, capacity_factor=2.0,
                  backend="mixnet", overlap_chunks=2),
)
OPT = AdamWConfig(lr=1e-3)
B, T = 4, 16


def batch_for(step):
    k = jax.random.PRNGKey(step)
    tok = jax.random.randint(k, (B, T), 0, CFG.vocab_size)
    return {"tokens": tok, "labels": jnp.roll(tok, -1, axis=1)}


def plan_for_step(step):
    """A toy control-plane: from step 2 on, apply a per-layer expert->slot
    perm plus a rotate-by-one wire re-address (what ControlPlane.apply
    pushes during real training)."""
    if step < 2:
        return None, None
    reps = CFG.pattern_repeats
    ev, _ = virtual_experts(CFG.moe.num_experts, EP)
    rng = np.random.RandomState(step)
    perm = jnp.asarray(
        np.stack([rng.permutation(ev) for _ in range(reps)]), jnp.int32)
    wire = jnp.asarray(
        np.stack([np.roll(np.arange(EP), l % EP) for l in range(reps)]),
        jnp.int32)
    return perm, wire


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=4)
    args = ap.parse_args()
    if jax.device_count() < STAGES * EP:
        raise SystemExit(
            f"needs {STAGES * EP} devices, have {jax.device_count()} "
            "(is XLA_FLAGS already set in the environment?)")

    pp_mesh = make_mesh((STAGES, EP), ("stage", "model"))
    pp_plan = make_plan(pp_mesh, fsdp=False)
    ref_mesh = make_mesh((EP,), ("model",))
    ref_plan = make_plan(ref_mesh)

    params, _, opt_state = init_all(
        jax.random.PRNGKey(0), CFG, make_plan(None), OPT)
    ref_params, ref_opt = jax.tree.map(jnp.copy, (params, opt_state))

    print(f"== PP(S={STAGES}) x EP({EP}) on {jax.device_count()} host "
          f"devices, microbatches=2, vs the flat EP({EP}) step ==")
    with use_mesh(pp_mesh):
        pp_step = jax.jit(make_pp_train_step(
            CFG, pp_plan, OPT, pp_mesh, pp_stages=STAGES, microbatches=2))
    with use_mesh(ref_mesh):
        ref_step = jax.jit(make_train_step(
            CFG, ref_plan, OPT, mesh=ref_mesh, microbatches=2))

    for step in range(args.steps):
        batch = batch_for(step)
        perm, wire = plan_for_step(step)
        with use_mesh(pp_mesh):
            params, opt_state, m = pp_step(
                params, opt_state, batch, perm, wire)
        with use_mesh(ref_mesh):
            ref_params, ref_opt, rm = ref_step(
                ref_params, ref_opt, batch, perm, wire)
        loss, ref_loss = float(m["loss"]), float(rm["loss"])
        np.testing.assert_allclose(loss, ref_loss, rtol=1e-5)
        tag = "  [reconfigured: perm+wire applied]" if perm is not None else ""
        print(f"step {step}: pp_loss={loss:.6f}  ref_loss={ref_loss:.6f}{tag}")

    # The whole trajectories agree, not just the scalar losses.
    for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(ref_params)):
        np.testing.assert_allclose(
            np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)
    print(f"PARITY_OK: {args.steps} steps, params match to 1e-5 "
          "across a live reconfiguration")


if __name__ == "__main__":
    main()
