"""MixNet control-plane walkthrough (paper Fig 7 + Fig 20 at small scale):

  1. generate realistic expert-load traces (temporally dynamic, sparse),
  2. feed them through the unified control-plane engine's lifecycle
     (observe -> end_step -> plan -> apply, DESIGN.md §3),
  3. COPILOT predicts the next layer's demand ahead of its gate (§B.1),
  4. run Algorithm 1 to allocate optical circuits (§5.2) via the fabric,
  5. price the SAME a2a through the CommRuntime AllToAll op (the object the
     trainer executes and netsim consumes, DESIGN.md §7) before/after the
     reconfiguration, including a wire re-addressing via the op's
     reconfigure hook,
  6. show the TPU analogue: per-layer expert re-placement relieving each
     layer's own bottleneck.

    PYTHONPATH=src python examples/reconfigure_fabric.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs.paper_models import MIXTRAL_8X7B
from repro.core.commruntime import AllToAll, CommSpec
from repro.core.controlplane import ControlPlane
from repro.core.copilot import CopilotPredictor, topk_accuracy
from repro.core.fabric import FabricConfig, MixNetFabric
from repro.core.netsim import GateTraceGenerator


def main():
    layers, experts, servers = 8, 16, 8
    trace = GateTraceGenerator(layers, experts, seed=1)
    engine = ControlPlane(layers, experts, num_devices=servers, fit_steps=100)

    print("== 1-3: observe traffic, fit COPILOT (engine lifecycle) ==")
    for _ in range(12):
        loads = trace.step()
        for l in range(layers):
            engine.observe(l, loads[l] * 1000)
        engine.end_step()
    loads = trace.step()
    for l in range(layers):
        engine.observe(l, loads[l] * 1000)
    pred = engine.predict_load(1)  # layer 1's load, forecast from layer 0
    acc = topk_accuracy(pred, loads[1], k=4)
    unchanged = topk_accuracy(
        CopilotPredictor.baseline_unchanged(loads[0]), loads[1], 4
    )
    print(f"COPILOT top-4 accuracy on the next layer: {acc:.2f} "
          f"(unchanged baseline: {unchanged:.2f})")

    print("\n== 4-5: Algorithm 1 circuit allocation, priced by the runtime ==")
    demand = trace.device_demand(loads[1], MIXTRAL_8X7B, servers)
    fab = MixNetFabric(FabricConfig(num_servers=servers, link_gbps=100))
    a2a = AllToAll(CommSpec.from_fabric(fab, servers))
    t_uniform = a2a.cost(fab, demand)  # demand-oblivious uniform circuits
    fab.prepare(demand)                # Algorithm 1 pushes the cross-map
    t_solved = a2a.cost(fab, demand)
    link = a2a.bytes_on_link(float(demand.sum()) / servers)
    print(f"circuits:\n{fab._circuits}")
    print(f"a2a completion ({a2a.__class__.__name__} op): "
          f"reconfigured={t_solved*1e3:.2f} ms  uniform={t_uniform*1e3:.2f} ms  "
          f"speedup={t_uniform/max(t_solved,1e-12):.2f}x")
    print(f"bytes-on-link per server: scale_up={link.scale_up/1e6:.1f} MB  "
          f"scale_out={link.scale_out/1e6:.1f} MB")
    # The reconfigure hook: a control-plane plan that re-addresses wire
    # chunks (here: rotate every destination one server) changes the PHYSICAL
    # demand the same op prices — no caller rewiring.
    rotated = a2a.reconfigure(dest_perm=np.roll(np.arange(servers), 1))
    print(f"after a wire re-address (rotate-by-1 dest_perm): "
          f"{rotated.cost(fab, demand)*1e3:.2f} ms on the same circuits")

    print("\n== 6: TPU analogue — per-layer expert re-placement ==")
    rng = np.random.default_rng(0)
    placer = ControlPlane(2, experts, num_devices=servers, use_copilot=False)
    for layer, hot in ((0, 9), (1, 3)):
        token_demand = rng.random((servers, experts)) * (
            rng.random((servers, experts)) < 0.3
        )
        token_demand[0, hot] = 50.0  # layer-specific hot (device 0 -> expert) pair
        plan = placer.plan(layer, token_demand)
        placer.apply(plan)
        print(f"layer {layer}: gain={plan.gain_bytes:.1f} bytes "
              f"({plan.reason}); expert->slot perm: "
              f"{placer.perm_stack()[layer].tolist()}")
    print("per-layer perms differ:",
          bool((placer.perm_stack()[0] != placer.perm_stack()[1]).any()))


if __name__ == "__main__":
    main()
