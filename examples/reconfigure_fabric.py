"""MixNet control-plane walkthrough (paper Fig 7 + Fig 20 at small scale):

  1. generate realistic expert-load traces (temporally dynamic, sparse),
  2. characterize the all-to-all traffic matrices (§5.1),
  3. fit MIXNET-COPILOT and predict the next layer's demand (§B.1),
  4. run Algorithm 1 to allocate optical circuits (§5.2),
  5. compare completion time vs a demand-oblivious uniform topology,
  6. show the TPU analogue: expert re-placement relieving the bottleneck.

    PYTHONPATH=src python examples/reconfigure_fabric.py
"""

import sys

sys.path.insert(0, "src")

import numpy as np

from repro.configs.paper_models import MIXTRAL_8X7B
from repro.core import topology as topo
from repro.core.copilot import CopilotPredictor, topk_accuracy
from repro.core.netsim import GateTraceGenerator
from repro.core.placement import solve_expert_placement
from repro.core.traffic import TrafficMonitor


def main():
    layers, experts, servers = 8, 16, 8
    trace = GateTraceGenerator(layers, experts, seed=1)
    monitor = TrafficMonitor(layers, experts)
    copilot = CopilotPredictor(layers, experts, fit_steps=100)

    print("== 1-3: monitor traffic, fit COPILOT ==")
    for it in range(12):
        loads = trace.step()
        for l in range(layers):
            monitor.record(l, loads[l] * 1000)
        copilot.update(monitor)
        monitor.advance()
    loads = trace.step()
    pred = copilot.predict(0, loads[0])
    acc = topk_accuracy(pred, loads[1], k=4)
    print(f"COPILOT top-4 accuracy on the next layer: {acc:.2f} "
          f"(unchanged baseline: "
          f"{topk_accuracy(copilot.baseline_unchanged(loads[0]), loads[1], 4):.2f})")

    print("\n== 4-5: Algorithm 1 circuit allocation ==")
    demand = trace.device_demand(loads[1], MIXTRAL_8X7B, servers)
    solved = topo.reconfigure_ocs(demand, alpha=6, num_servers=servers,
                                  experts_per_server=1)
    pair = np.triu(np.maximum(demand, demand.T), 1)
    t_solved = topo.topology_completion_time(solved.circuits, pair, 12.5e9, 0.25 * 12.5e9)
    t_uniform = topo.topology_completion_time(
        topo.uniform_topology(servers, 6), pair, 12.5e9, 0.25 * 12.5e9)
    print(f"circuits:\n{solved.circuits}")
    print(f"a2a completion: reconfigured={t_solved*1e3:.2f} ms  "
          f"uniform={t_uniform*1e3:.2f} ms  "
          f"speedup={t_uniform/max(t_solved,1e-12):.2f}x")

    print("\n== 6: TPU analogue — expert re-placement ==")
    rng = np.random.default_rng(0)
    token_demand = rng.random((servers, experts)) * (rng.random((servers, experts)) < 0.3)
    token_demand[0, 9] = 50.0  # hot (device 0 -> expert 9) pair
    plan = solve_expert_placement(token_demand, experts // servers)
    print(f"bytes-on-wire before={plan.cost_before:.1f} after={plan.cost_after:.1f} "
          f"(gain {100*plan.gain/max(plan.cost_before,1e-9):.0f}%)")
    print(f"expert->slot permutation: {plan.perm.tolist()}")


if __name__ == "__main__":
    main()
