"""Quickstart: train a small MoE LM end-to-end with the full MixNet runtime
— hierarchical-a2a expert dispatch, traffic monitoring, COPILOT fitting and
runtime expert re-placement — on whatever devices are available.

    PYTHONPATH=src python examples/quickstart.py [--steps 40]
"""

import argparse
import sys

sys.path.insert(0, "src")

import numpy as np

from repro.data.pipeline import SyntheticLM
from repro.models.config import ModelConfig, MoEConfig
from repro.optim.adamw import AdamWConfig
from repro.parallel.sharding import make_plan
from repro.train.trainer import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=40)
    ap.add_argument("--backend", choices=("mixnet", "einsum"), default="mixnet")
    args = ap.parse_args()

    cfg = ModelConfig(
        name="quickstart-moe",
        family="moe",
        num_layers=4,
        d_model=128,
        num_heads=4,
        num_kv_heads=2,
        d_ff=0,
        vocab_size=512,
        dtype="float32",
        remat="none",
        moe=MoEConfig(num_experts=8, top_k=2, d_ff=256, capacity_factor=2.0,
                      backend=args.backend),
    )
    opt = AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=args.steps * 2)
    tcfg = TrainerConfig(
        total_steps=args.steps,
        log_every=5,
        reconfig_every=8,  # the MixNet runtime reconfiguration cadence
        reconfig_min_gain=0.02,
        ckpt_every=0,
    )
    plan = make_plan(None)
    trainer = Trainer(cfg, opt, tcfg, plan, seed=0)
    data = SyntheticLM(cfg.vocab_size, seq_len=64, global_batch=8, seed=0)

    print(f"training {cfg.name} ({cfg.param_count()/1e6:.1f}M params, "
          f"{cfg.moe.num_experts} experts top-{cfg.moe.top_k}, "
          f"dispatch={cfg.moe.backend})")
    log = trainer.train(iter(data))
    for m in log:
        if m["step"] % tcfg.log_every == 0 or m["step"] == 1:
            print(f"step {m['step']:4d}  loss {float(m['loss']):.3f}  "
                  f"balance {float(m['balance_loss']):.3f}  "
                  f"gnorm {float(m['grad_norm']):.2f}  "
                  f"{m['step_time_s']*1e3:.0f} ms")
    first = np.mean([float(m["loss"]) for m in log[:5]])
    last = np.mean([float(m["loss"]) for m in log[-5:]])
    print(f"\nloss {first:.3f} -> {last:.3f}  "
          f"(runtime reconfigurations: {trainer.reconfig_count}, "
          f"straggler events: {trainer.straggler_events})")


if __name__ == "__main__":
    main()
