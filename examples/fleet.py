"""Multi-replica serving fleet with gate-locality steering (DESIGN.md §12).

Two ServeEngine replicas behind one SLO-aware admission queue: requests are
steered to the replica whose resident expert mix best matches their
region's predicted mix, one replica is gracefully drained mid-run (its
queued work re-steers, its in-flight work finishes), and the run ends by
proving the fleet guarantee — every steered/re-steered request generated
tokens bit-identical to unsteered single-replica serving.

    PYTHONPATH=src python examples/fleet.py [--arch grok-1-314b]
        [--mix agentic] [--requests 8] [--policy locality]
        [--drain-tick 3]
"""

import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.configs import ARCH_NAMES, get_reduced
from repro.models.transformer import init_model
from repro.obs import metrics, trace
from repro.parallel.sharding import make_plan
from repro.serve.batching import Request
from repro.serve.engine import ServeConfig, ServeEngine
from repro.serve.fleet import FleetConfig, FleetEngine, fleet_requests
from repro.serve.workload import MIXES, WorkloadGenerator, clamp_requests


def make_replica(params, cfg, plan, args) -> ServeEngine:
    scfg = ServeConfig(
        slots=args.slots,
        max_len=args.max_len,
        num_devices=args.num_devices,
        external_control=True,  # the FleetEngine decides when to reconfigure
        num_regions=MIXES[args.mix].num_regions,
        reconfig_min_gain=0.0,
    )
    return ServeEngine(jax.tree.map(lambda a: a, params), cfg, plan, scfg)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=ARCH_NAMES, default="grok-1-314b")
    ap.add_argument("--mix", choices=sorted(MIXES), default="agentic")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--replicas", type=int, default=2)
    ap.add_argument("--policy", default="locality",
                    choices=["locality", "least_loaded", "round_robin"])
    ap.add_argument("--slots", type=int, default=2)
    ap.add_argument("--max-len", type=int, default=48)
    ap.add_argument("--num-devices", type=int, default=4)
    ap.add_argument("--drain-tick", type=int, default=3)
    ap.add_argument("--restore-tick", type=int, default=10)
    ap.add_argument("--reconfig-every", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace", default="",
                    help="export a Perfetto trace of the run to this path")
    ap.add_argument("--metrics", default="",
                    help="dump the metrics-registry snapshot to this path")
    args = ap.parse_args()
    if args.trace:
        trace.enable()

    cfg = get_reduced(args.arch)
    if cfg.encoder_layers or not cfg.is_moe:
        raise SystemExit("the fleet demo needs a pure-decoder MoE arch")
    if cfg.moe.num_experts % args.num_devices:
        args.num_devices = 1
    plan = make_plan(None)
    params, _ = init_model(jax.random.PRNGKey(0), cfg, plan)

    gen = WorkloadGenerator(args.mix, seed=args.seed, vocab_size=cfg.vocab_size)
    out_cap = max(1, min(10, args.max_len // 4))
    raw = clamp_requests(gen.generate(args.requests),
                         prompt_max=args.max_len - out_cap - 2,
                         max_new=out_cap)
    freqs = fleet_requests(raw, gen)

    print(f"fleet of {args.replicas}x reduced {args.arch} on mix={args.mix}: "
          f"{len(freqs)} requests, policy={args.policy}, "
          f"drain replica 1 @ tick {args.drain_tick}")
    fleet = FleetEngine(
        [make_replica(params, cfg, plan, args) for _ in range(args.replicas)],
        FleetConfig(policy=args.policy, reconfig_every=args.reconfig_every),
    )
    rep = fleet.run(
        freqs,
        drain_at={1: args.drain_tick} if args.replicas > 1 else None,
        restore_at={1: args.restore_tick} if args.replicas > 1 else None,
    )

    print("  steering/reconfig decision log:")
    for d in fleet.decision_log:
        rest = {k: v for k, v in d.items() if k not in ("tick", "kind")}
        print(f"    tick {d['tick']:>4}: {d['kind']:<8} {rest}")
    print(f"  completed={rep.completed}/{rep.requests} in {rep.ticks} fleet "
          f"ticks; steer reasons: {rep.steer_reasons}; "
          f"fleet reconfigurations: {rep.reconfig_events}")
    print(f"  TTFT p50/p99 = {rep.ttft_ticks_p50:.0f}/{rep.ttft_ticks_p99:.0f}"
          f" ticks; SLO attainment: {rep.slo_attainment}")
    assert rep.completed == len(freqs), "fleet stranded requests"

    # the fleet guarantee: steering/drain never changed a single token
    single = make_replica(params, cfg, plan, args)
    for fr in sorted(freqs, key=lambda f: (f.arrival_s, f.rid)):
        single.submit(Request(rid=fr.rid, prompt=fr.prompt,
                              max_new_tokens=fr.max_new_tokens,
                              eos_id=fr.eos_id, region=fr.region))
    while single.batcher.busy:
        single.step()
    ref = {r.rid: list(r.out) for r in single.batcher.finished}
    assert rep.outputs == ref, "steering changed generated tokens"
    print("  parity: fleet tokens bit-identical to single-replica serving ✓")

    if args.trace:
        n = trace.export(args.trace)
        failures = trace.validate_file(args.trace)
        assert not failures, f"trace schema failures: {failures[:3]}"
        print(f"  trace: {n} events -> {args.trace} (one merged timeline: "
              "fleet + every replica; open in ui.perfetto.dev)")
    if args.metrics:
        metrics.default().to_json(args.metrics)
        print(f"  metrics snapshot -> {args.metrics}")


if __name__ == "__main__":
    main()
